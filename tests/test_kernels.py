"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles, with
shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph.storage import INVALID
from repro.kernels.intersect import ops as intersect_ops
from repro.kernels.intersect.intersect import (
    fused_extend_kernel,
    fused_verify_kernel,
    lex_bounds_kernel,
    multiway_membership_kernel,
)
from repro.kernels.intersect.ref import (
    fused_extend_ref,
    fused_verify_ref,
    lex_bounds_ref,
    multiway_membership_ref,
)
from repro.kernels.flash_attention.flash_attention import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.ops import attention_chunked
from repro.kernels.rwkv6.ref import rwkv6_ref
from repro.kernels.rwkv6.rwkv6 import rwkv6_kernel
from repro.kernels.rwkv6.ops import rwkv6_chunked, rwkv6_decode_step

RNG = np.random.default_rng(42)


def _sorted_rows(b, e, d, vmax=500):
    others = np.full((b, e, d), INVALID, np.int32)
    for i in range(b):
        for j in range(e):
            k = RNG.integers(1, d)
            vals = np.unique(RNG.integers(0, vmax, size=k)).astype(np.int32)
            others[i, j, : len(vals)] = vals
    return others


@pytest.mark.parametrize("shape", [(8, 1, 128), (16, 2, 256), (8, 3, 384), (24, 4, 128)])
def test_intersect_kernel_matches_ref(shape):
    b, e, d = shape
    others = _sorted_rows(b, e, d)
    cands = RNG.integers(0, 500, size=(b, d)).astype(np.int32)
    cands[RNG.random((b, d)) < 0.2] = INVALID
    ref = multiway_membership_ref(jnp.asarray(cands), jnp.asarray(others))
    ker = multiway_membership_kernel(jnp.asarray(cands), jnp.asarray(others), interpret=True)
    assert bool(jnp.all(ref == ker))


@pytest.mark.parametrize("b", [1, 3, 7, 9, 13, 17, 23])
def test_intersect_dispatch_pads_remainder_batches(b):
    """B % TILE_B != 0 must run through the padded kernel (force_kernel) and
    match the ref — the silent ref fallback regression."""
    e, d = 2, 128
    others = _sorted_rows(b, e, d)
    cands = RNG.integers(0, 500, size=(b, d)).astype(np.int32)
    cands[RNG.random((b, d)) < 0.2] = INVALID
    ref = multiway_membership_ref(jnp.asarray(cands), jnp.asarray(others))
    ker = intersect_ops.multiway_membership(
        jnp.asarray(cands), jnp.asarray(others), force_kernel=True
    )
    assert ker.shape == (b, d)
    assert bool(jnp.all(ref == ker))


def _slab_table(r, d, vmax=300):
    t = np.full((r, d), INVALID, np.int32)
    for i in range(r):
        k = RNG.integers(1, d)
        vals = np.unique(RNG.integers(0, vmax, size=k)).astype(np.int32)
        t[i, : len(vals)] = vals
    return jnp.asarray(t)


def _fused_inputs(b, e, k, d, r0, r1):
    tab0, tab1 = _slab_table(r0, d), _slab_table(r1, d)
    idx = jnp.asarray(
        np.stack([RNG.integers(0, r0, (b, e)), RNG.integers(0, r1, (b, e))]).astype(np.int32)
    )
    sel = jnp.asarray(RNG.integers(0, 2, (b, e)).astype(np.int32))
    ok = jnp.asarray((RNG.random((b, e)) < 0.85).astype(np.int32))
    rows = jnp.asarray(RNG.integers(0, 300, (b, k)).astype(np.int32))
    return tab0, tab1, idx, sel, ok, rows


@pytest.mark.parametrize("b,e,k,lt,gt", [
    (6, 1, 2, (), ()),
    (8, 2, 3, (1,), ()),
    (11, 3, 4, (0,), (2,)),
])
def test_fused_extend_kernel_matches_ref(b, e, k, lt, gt):
    tab0, tab1, idx, sel, ok, rows = _fused_inputs(b, e, k, 128, 29, 41)
    c_ref, m_ref = fused_extend_ref(tab0, tab1, idx, sel, ok, rows, lt=lt, gt=gt)
    c_ker, m_ker = fused_extend_kernel(
        tab0, tab1, idx, sel, ok, rows, lt=lt, gt=gt, interpret=True
    )
    assert bool(jnp.all(c_ref == c_ker))
    assert bool(jnp.all(m_ref == m_ker))


@pytest.mark.parametrize("b,e,k,vpos", [(5, 1, 3, 0), (9, 2, 4, 2), (8, 3, 3, 1)])
def test_fused_verify_kernel_matches_ref(b, e, k, vpos):
    tab0, tab1, idx, sel, ok, rows = _fused_inputs(b, e, k, 128, 23, 31)
    # make some targets actual members so the True branch is exercised
    rows = rows.at[0, vpos].set(int(tab0[int(idx[0, 0, 0]), 0]))
    ref = fused_verify_ref(tab0, tab1, idx, sel, ok, rows, vpos=vpos)
    ker = fused_verify_kernel(tab0, tab1, idx, sel, ok, rows, vpos=vpos, interpret=True)
    assert bool(jnp.all(ref == ker))


@pytest.mark.parametrize("cap,kk,bq", [(64, 1, 7), (200, 2, 17), (384, 3, 8)])
def test_lex_bounds_kernel_matches_ref(cap, kk, bq):
    nk = int(cap * 0.8)
    keys = np.full((cap, kk), INVALID, np.int32)
    filled = RNG.integers(0, 30, (nk, kk)).astype(np.int32)
    keys[:nk] = filled[np.lexsort(filled[:, ::-1].T)]
    q = RNG.integers(0, 30, (bq, kk)).astype(np.int32)
    q[RNG.random(bq) < 0.25] = INVALID - 1  # the invalid-query convention
    keys, q = jnp.asarray(keys), jnp.asarray(q)
    lo_r, hi_r = lex_bounds_ref(keys, q)
    lo_k, hi_k = lex_bounds_kernel(keys, q, interpret=True)
    np.testing.assert_array_equal(np.asarray(lo_r), np.asarray(lo_k))
    np.testing.assert_array_equal(np.asarray(hi_r), np.asarray(hi_k))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "bh,sq,sk,dh,causal,cap",
    [
        (2, 128, 128, 64, True, None),
        (1, 256, 256, 128, True, None),
        (2, 128, 256, 64, False, None),
        (1, 128, 128, 64, True, 30.0),
        (1, 64, 192, 64, True, None),   # decode-like: q is a suffix of kv
    ],
)
def test_flash_attention_matches_ref(bh, sq, sk, dh, causal, cap, dtype):
    q = jnp.asarray(RNG.standard_normal((bh, sq, dh)), dtype)
    k = jnp.asarray(RNG.standard_normal((bh, sk, dh)), dtype)
    v = jnp.asarray(RNG.standard_normal((bh, sk, dh)), dtype)
    ref = attention_ref(q, k, v, causal=causal, softcap=cap).astype(jnp.float32)
    ker = flash_attention_kernel(
        q, k, v, causal=causal, softcap=cap, tq=64, tk=64, interpret=True
    ).astype(jnp.float32)
    chk = attention_chunked(q, k, v, causal=causal, softcap=cap, chunk=96).astype(jnp.float32)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(ref - ker))) < tol
    assert float(jnp.max(jnp.abs(ref - chk))) < tol


@pytest.mark.parametrize("bh,t,kd,vd,chunk", [(2, 64, 32, 32, 16), (2, 128, 64, 64, 32), (1, 96, 64, 64, 32)])
def test_rwkv6_kernel_matches_ref(bh, t, kd, vd, chunk):
    r = jnp.asarray(RNG.standard_normal((bh, t, kd)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, t, kd)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, t, vd)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.6, 0.999, (bh, t, kd)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((bh, kd)) * 0.3, jnp.float32)
    ref = rwkv6_ref(r, k, v, w, u)
    ker = rwkv6_kernel(r, k, v, w, u, chunk=chunk, interpret=True)
    chk = rwkv6_chunked(r, k, v, w, u, chunk=chunk)
    assert float(jnp.max(jnp.abs(ref - ker))) < 1e-3
    assert float(jnp.max(jnp.abs(ref - chk))) < 1e-3


def test_rwkv6_decode_matches_ref():
    bh, t, kd, vd = 2, 12, 16, 16
    r = jnp.asarray(RNG.standard_normal((bh, t, kd)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, t, kd)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, t, vd)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.6, 0.999, (bh, t, kd)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((bh, kd)) * 0.3, jnp.float32)
    ref = rwkv6_ref(r, k, v, w, u)
    S = jnp.zeros((bh, kd, vd))
    outs = []
    for i in range(t):
        S, o = rwkv6_decode_step(S, r[:, i], k[:, i], v[:, i], w[:, i], u)
        outs.append(o)
    assert float(jnp.max(jnp.abs(jnp.stack(outs, 1) - ref))) < 1e-4


def test_rwkv6_chunked_state_continuation():
    """Chunked scan's returned state continues exactly into decode steps."""
    bh, t, kd, vd = 1, 32, 16, 16
    r = jnp.asarray(RNG.standard_normal((bh, t + 4, kd)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, t + 4, kd)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, t + 4, vd)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.6, 0.999, (bh, t + 4, kd)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((bh, kd)) * 0.3, jnp.float32)
    full = rwkv6_ref(r, k, v, w, u)
    _, S = rwkv6_chunked(r[:, :t], k[:, :t], v[:, :t], w[:, :t], u, chunk=8, return_state=True)
    outs = []
    for i in range(t, t + 4):
        S, o = rwkv6_decode_step(S, r[:, i], k[:, i], v[:, i], w[:, i], u)
        outs.append(o)
    assert float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full[:, t:]))) < 1e-3
