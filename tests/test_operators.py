"""Unit tests for the vectorised operator kernels."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as ops
from repro.graph.storage import INVALID


def test_compact_packs_front():
    rows = jnp.asarray(np.arange(20).reshape(10, 2), jnp.int32)
    mask = jnp.asarray([True, False, True, False, True, False, False, True, False, False])
    out, n = ops.compact(rows, mask, 16)
    assert int(n) == 4
    np.testing.assert_array_equal(np.asarray(out[:4, 0]), [0, 4, 8, 14])
    assert np.all(np.asarray(out[4:]) == INVALID)


def test_queue_append_pop_roundtrip():
    buf = jnp.full((64, 3), INVALID, jnp.int32)
    rows = jnp.asarray(np.arange(30).reshape(10, 3), jnp.int32)
    buf, n = ops.queue_append(buf, jnp.int32(0), rows, jnp.int32(10))
    got, take, rem = ops.queue_pop(buf, n, 4)
    assert int(take) == 4 and int(rem) == 6
    np.testing.assert_array_equal(np.asarray(got[:4]), np.arange(18, 30).reshape(4, 3))


def test_row_membership_sorted():
    rows = jnp.asarray([[1, 3, 5, INVALID], [2, 4, 6, 8]], jnp.int32)
    queries = jnp.asarray([[3, 4, 1, INVALID], [8, 2, 5, 7]], jnp.int32)
    m = ops.row_membership(rows, queries)
    np.testing.assert_array_equal(
        np.asarray(m), [[True, False, True, False], [True, True, False, False]]
    )


def test_join_prepare_probe_vs_bruteforce():
    rng = np.random.default_rng(0)
    nl, nr = 200, 80
    lbuf = rng.integers(0, 12, size=(256, 3)).astype(np.int32)
    rbuf = rng.integers(0, 12, size=(128, 2)).astype(np.int32)
    key_left, key_right = (1,), (0,)
    skeys, sbuf = ops.join_prepare(jnp.asarray(lbuf), jnp.int32(nl), key_left)
    out, n, overflow = ops.join_probe(
        skeys, sbuf, jnp.asarray(rbuf), jnp.int32(nr),
        key_right, (1,), (), (), 1 << 14,
    )
    assert not bool(overflow)
    got = {tuple(map(int, r)) for r in np.asarray(out[: int(n)])}
    want = set()
    for i in range(nl):
        for j in range(nr):
            if lbuf[i, 1] == rbuf[j, 0]:
                want.add((int(lbuf[i, 0]), int(lbuf[i, 1]), int(lbuf[i, 2]), int(rbuf[j, 1])))
    assert got == want


def test_join_probe_cross_filters():
    lbuf = jnp.asarray([[1, 5, 2], [3, 5, 4]], jnp.int32)
    rbuf = jnp.asarray([[5, 2], [5, 9]], jnp.int32)
    skeys, sbuf = ops.join_prepare(
        jnp.pad(lbuf, ((0, 6), (0, 0)), constant_values=0), jnp.int32(2), (1,)
    )
    out, n, _ = ops.join_probe(
        skeys, sbuf, jnp.pad(rbuf, ((0, 6), (0, 0)), constant_values=0), jnp.int32(2),
        (0,), (1,), ((2, 3),), (), 64,
    )  # cross_neq on (col2, col3): drops (…,2,…,2)
    got = {tuple(map(int, r)) for r in np.asarray(out[: int(n)])}
    assert (1, 5, 2, 2) not in got
    assert (1, 5, 2, 9) in got and (3, 5, 4, 2) in got


def test_lexsort_rows():
    cols = jnp.asarray([[2, 1], [1, 9], [2, 0], [1, 3]], jnp.int32)
    order = ops.lexsort_rows(cols)
    np.testing.assert_array_equal(np.asarray(order), [3, 1, 2, 0])


def test_scan_batch_filters():
    src = jnp.asarray([0, 0, 1, 1, 2, 2, 0, 0], jnp.int32)
    dst = jnp.asarray([1, 2, 0, 2, 0, 1, INVALID, INVALID], jnp.int32)
    rows, n = ops.scan_batch(src, dst, jnp.int32(0), jnp.int32(6), 8, (1,), ())
    # lt=(1,): keep src < dst only
    got = {tuple(map(int, r)) for r in np.asarray(rows[: int(n)])}
    assert got == {(0, 1), (0, 2), (1, 2)}


def test_partition_rows_by_key_groups_by_dest_shard():
    rows = jnp.asarray(
        [[0, 1], [5, 2], [3, 9], [7, 4], [2, 2], [9, 9]], jnp.int32
    )
    valid = jnp.asarray([True, True, True, True, False, True])
    send = ops.partition_rows_by_key(rows, valid, rows[:, 0], 4)
    assert send.shape == (4, 6, 2)
    got = {
        d: [tuple(map(int, r)) for r in np.asarray(send[d]) if r[0] != INVALID]
        for d in range(4)
    }
    assert got[0] == [(0, 1)]
    assert got[1] == [(5, 2), (9, 9)]      # 5 % 4 == 9 % 4 == 1
    assert got[2] == []                    # the only key%4==2 row was invalid
    assert got[3] == [(3, 9), (7, 4)]
    # every valid row lands exactly once, invalid rows are dropped
    assert sum(len(v) for v in got.values()) == 5
