"""Flowcheck static-verifier tests (repro/analysis/flowcheck.py).

Three legs:

* clean inputs — optimiser/translator output for paper queries must produce
  zero findings, and the engines' mandatory pre-flight must not reject them;
* seeded bad fixtures — each known-malformed plan/dataflow must fire exactly
  the expected rule id(s);
* service admission — a malformed tenant submission is rejected with
  structured diagnostics at admission, without leaking a slot-pool lease or
  the tenant's inflight count, and without disturbing well-formed tenants.
"""
import dataclasses

import pytest

from repro.analysis import fixtures
from repro.analysis.diagnostics import Diagnostic, FlowcheckError, errors
from repro.analysis.flowcheck import check_flow, check_plan, check_query, verify_flow
from repro.core.cost import GraphStats
from repro.core.dataflow import Dataflow, OpDesc, merge_flows, translate
from repro.core.engine import EngineConfig, HugeEngine
from repro.core.optimizer import optimal_plan
from repro.core.query import PAPER_QUERIES, QueryGraph, triangle
from repro.graph import powerlaw_graph
from repro.serve.graph_service import (
    DONE,
    REJECTED,
    GraphQueryRequest,
    GraphService,
    ServiceConfig,
)


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(256, 5.0, seed=3)


STATS = GraphStats.synthetic(1 << 10, 6.0)


# ---------------------------------------------------------------------------
# clean inputs verify clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qname", ["q1", "q2", "q5", "q8"])
@pytest.mark.parametrize("space", ["huge", "seed", "bigjoin"])
def test_planner_output_verifies(qname, space):
    plan = optimal_plan(PAPER_QUERIES[qname], STATS, 8, space)
    assert errors(check_plan(plan)) == []
    flow = translate(plan)
    assert errors(check_flow(flow)) == []


def test_merged_flow_verifies():
    flows = [translate(optimal_plan(PAPER_QUERIES[q], STATS, 8, "huge"))
             for q in ("q1", "q2")]
    merged, _ = merge_flows(flows)
    assert errors(check_flow(merged)) == []


def test_queue_pricing_within_budget():
    flow = translate(optimal_plan(PAPER_QUERIES["q1"], STATS, 8, "huge"))
    diags = check_flow(flow, cfg=EngineConfig(), d_pad=64,
                       max_cells=ServiceConfig().total_queue_cells)
    assert errors(diags) == []


def test_engine_preflight_accepts_good_query(graph):
    eng = HugeEngine(graph, EngineConfig(num_machines=4, batch_size=256))
    res = eng.run(triangle())
    assert res.count > 0


# ---------------------------------------------------------------------------
# seeded bad fixtures fire the expected rules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(
    n for n in fixtures.FIXTURES if n != "bad-kernel-source"))
def test_fixture_fires_expected_rules(name):
    diags, expected = fixtures.run_fixture(name)
    fired = {d.rule for d in diags}
    for rule in expected:
        assert rule in fired, f"{name}: {rule} missing from {sorted(fired)}"
    assert all(isinstance(d, Diagnostic) for d in diags)


def test_query_checks():
    assert {d.rule for d in check_query(QueryGraph.from_edges([(0, 1), (2, 3)]))} \
        == {"query-disconnected"}
    empty = dataclasses.replace(triangle(), edges=frozenset())
    assert "query-empty" in {d.rule for d in check_query(empty)}


def test_engine_preflight_rejects_bad_flow(graph):
    eng = HugeEngine(graph, EngineConfig(num_machines=4))
    with pytest.raises(FlowcheckError) as ei:
        eng.prepare(fixtures.bad_join_key_flow())
    assert any(d.rule == "join-key-incompatible" for d in ei.value.diagnostics)


def test_verify_flow_error_carries_diagnostics():
    with pytest.raises(FlowcheckError) as ei:
        verify_flow(fixtures.dangling_sink_flow())
    assert any(d.rule == "orphan-op" for d in ei.value.diagnostics)
    assert "orphan-op" in str(ei.value)


def test_sinkless_flow_rejected():
    flow = Dataflow(ops=[OpDesc(kind="scan", schema=(0, 1), scan_edge=(0, 1))],
                    query_name="sinkless")
    assert "no-sink" in {d.rule for d in check_flow(flow)}


# ---------------------------------------------------------------------------
# service admission: structured rejection, no leaked leases
# ---------------------------------------------------------------------------

def _svc(graph, **kw):
    base = dict(queue_capacity=1 << 10, join_buffer_capacity=1 << 12,
                tick_steps=16, max_active=4)
    base.update(kw)
    return GraphService(graph, ServiceConfig(**base))


def test_service_rejects_malformed_dataflow_at_admission(graph):
    svc = _svc(graph)
    t = svc.submit(GraphQueryRequest(tenant="adv", query=fixtures.bad_join_key_flow()))
    assert t.status == "queued"
    svc.tick()
    assert t.status == REJECTED
    assert any(d.rule == "join-key-incompatible" for d in t.diagnostics)
    assert "flowcheck" in t.error
    # nothing leased, tenant inflight released, no active session left behind
    assert svc.pool.leased_cells == 0
    assert svc.tenant_usage("adv")["inflight"] == 0
    assert not svc.active


def test_service_rejects_disconnected_plan_at_admission(graph):
    svc = _svc(graph)
    t = svc.submit(GraphQueryRequest(tenant="adv", query=fixtures.disconnected_plan()))
    svc.tick()
    assert t.status == REJECTED
    assert any(d.rule == "subquery-disconnected" for d in t.diagnostics)
    assert svc.pool.leased_cells == 0


def test_service_still_serves_good_tenants_after_rejection(graph):
    svc = _svc(graph)
    bad = svc.submit(GraphQueryRequest(tenant="adv", query=fixtures.pull_join_flow()))
    good = svc.submit(GraphQueryRequest(tenant="ok", query="q1"))
    svc.run_until_idle()
    assert bad.status == REJECTED
    assert any(d.rule == "comm-illegal" for d in bad.diagnostics)
    assert good.status == DONE and good.count > 0
    assert svc.pool.leased_cells == 0  # everything released at idle
