"""Multi-device tests (8 host devices via subprocess — XLA device count must
be set before jax initialises, so these run in fresh interpreters)."""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ, PYTHONPATH="src",
           XLA_FLAGS="--xla_force_host_platform_device_count=8")


def run_py(code: str, timeout=540, devices=8) -> str:
    env = dict(ENV, XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    r = subprocess.run([sys.executable, "-u", "-c", textwrap.dedent(code)],
                       env=env, cwd="/root/repo", capture_output=True,
                       text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_distributed_engine_matches_oracle():
    out = run_py("""
        import jax
        from repro.graph import erdos_renyi
        from repro.graph.oracle import count_instances
        from repro.core import query as Q
        from repro.core.distributed import DistributedEngine, DistConfig
        mesh = jax.make_mesh((8,), ("shards",))
        g = erdos_renyi(250, 6.0, seed=11)
        eng = DistributedEngine(g, mesh, DistConfig(batch_size=128, queue_capacity=1<<14))
        for qname in ("q1", "q2", "q3"):
            q = Q.PAPER_QUERIES[qname]
            count, _ = eng.run(q)
            oracle = count_instances(g, list(q.edges))
            assert count == oracle, (qname, count, oracle)
            print(qname, "ok", count)
    """)
    assert out.count("ok") == 3


def test_distributed_work_stealing_toggle():
    out = run_py("""
        import jax
        from repro.graph import powerlaw_graph
        from repro.graph.oracle import count_instances
        from repro.core import query as Q
        from repro.core.distributed import DistributedEngine, DistConfig
        mesh = jax.make_mesh((8,), ("shards",))
        g = powerlaw_graph(300, 6.0, seed=12)
        q = Q.PAPER_QUERIES["q1"]
        oracle = count_instances(g, list(q.edges))
        for rb in (True, False):
            eng = DistributedEngine(g, mesh, DistConfig(batch_size=128, queue_capacity=1<<14, rebalance=rb))
            count, _ = eng.run(q)
            assert count == oracle, (rb, count, oracle)
        print("stealing ok")
    """)
    assert "stealing ok" in out


def test_distributed_push_join_hybrid_plans():
    """The tentpole claim: optimiser plans containing PUSH-JOINs execute
    end-to-end on the 4-shard shard_map engine — hash-a2a shuffles, local
    probes, no single-process fallback — and match the networkx oracle on
    power-law and clique-heavy graphs."""
    out = run_py("""
        import jax
        from repro.graph import powerlaw_graph, ring_of_cliques
        from repro.graph.oracle import count_instances
        from repro.core import query as Q
        from repro.core.distributed import DistributedEngine, DistConfig
        mesh = jax.make_mesh((4,), ("shards",))
        pl = powerlaw_graph(240, 5.0, seed=3)
        cl = ring_of_cliques(24, 5)
        cases = [
            (pl, "q1", "seed"),        # push-only space: edge scans + hash join
            (pl, "q7", "huge"),        # hybrid: optimiser mixes extends + join
            (cl, "q2", "seed"),
            (cl, "q8", "starjoin"),    # two chained joins
        ]
        engines = {}
        for g, qname, space in cases:
            if id(g) not in engines:
                engines[id(g)] = DistributedEngine(
                    g, mesh, DistConfig(batch_size=128, queue_capacity=1 << 14))
            eng = engines[id(g)]
            count, stats = eng.run(Q.PAPER_QUERIES[qname], space=space)
            assert stats["engine"] == "shard_map"     # no single-process fallback
            assert stats["joins"] >= 1, (qname, space)
            assert stats["probe_batches"] > 0, (qname, space)
            oracle = count_instances(g, list(Q.PAPER_QUERIES[qname].edges))
            assert count == oracle, (qname, space, count, oracle)
            print(qname, space, "ok", count, "shuffled", stats["shuffle_rows"])
    """, devices=4)
    assert out.count("ok") == 4


def test_distributed_fused_hot_path_matches_unfused():
    """The fused extend/verify and probe kernels inside the shard_map engine
    produce counts identical to the unfused collectives path and the oracle —
    ref twins at scale, plus a small interpret-mode (force_kernel) run that
    executes real Pallas kernel semantics inside shard_map."""
    out = run_py("""
        import jax
        from repro.graph import powerlaw_graph, ring_of_cliques
        from repro.graph.oracle import count_instances
        from repro.core import query as Q
        from repro.core.distributed import DistributedEngine, DistConfig
        mesh = jax.make_mesh((4,), ("shards",))
        pl = powerlaw_graph(240, 5.0, seed=3)
        for qname, space in (("q1", "huge"), ("q2", "seed"), ("q7", "huge")):
            q = Q.PAPER_QUERIES[qname]
            oracle = count_instances(pl, list(q.edges))
            base, _ = DistributedEngine(pl, mesh, DistConfig(
                batch_size=128, queue_capacity=1 << 14)).run(q, space=space)
            fused, _ = DistributedEngine(pl, mesh, DistConfig(
                batch_size=128, queue_capacity=1 << 14, fused=True)).run(q, space=space)
            assert base == fused == oracle, (qname, space, base, fused, oracle)
            print(qname, space, "ok", fused)
        # interpret-mode kernels inside shard_map on a tiny clique graph
        cl = ring_of_cliques(4, 5)
        q = Q.PAPER_QUERIES["q2"]
        oracle = count_instances(cl, list(q.edges))
        fused, _ = DistributedEngine(cl, mesh, DistConfig(
            batch_size=16, queue_capacity=1 << 10, join_buffer_capacity=1 << 9,
            join_out_capacity=1 << 10, fused=True, force_kernel=True)).run(q)
        assert fused == oracle, (fused, oracle)
        print("interpret ok", fused)
    """, devices=4)
    assert out.count("ok") == 4


def test_distributed_mixed_tenants_run_concurrent():
    """Three tenants' queries execute through ONE shard_map engine in a single
    merged scheduler pass (tenant-tagged runtimes); per-tenant counts must
    equal both isolated runs and the networkx oracle."""
    out = run_py("""
        import jax
        from repro.graph import erdos_renyi
        from repro.graph.oracle import count_instances
        from repro.core import query as Q
        from repro.core.distributed import DistributedEngine, DistConfig
        mesh = jax.make_mesh((4,), ("shards",))
        g = erdos_renyi(200, 5.0, seed=13)
        eng = DistributedEngine(g, mesh, DistConfig(batch_size=128, queue_capacity=1<<14))
        queries = [Q.PAPER_QUERIES[n] for n in ("q1", "q2", "q3")]
        counts, stats = eng.run_concurrent(queries)
        assert stats["tenants"] == 3 and stats["per_tenant_matches"] == counts
        labels = [rt.label for rt in eng._last_runtimes]
        assert any(l.startswith("t0:") for l in labels)
        assert any(l.startswith("t2:") for l in labels)
        for q, got in zip(queries, counts):
            alone, _ = eng.run(q)
            oracle = count_instances(g, list(q.edges))
            assert got == alone == oracle, (q.name, got, alone, oracle)
            print(q.name, "ok", got)
    """, devices=4)
    assert out.count("ok") == 3


def test_moe_push_pull_equivalence_multidevice():
    """HUGE's core claim for the LM substrate: push and pull modes are the
    same logical join — identical outputs, different collectives."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.models import sharding as shd
        from repro.models.moe import moe_init, moe_block
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        key = jax.random.key(0)
        params = moe_init(key, 32, 64, 8, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (8, 16, 32), jnp.float32)
        with shd.activate(mesh), mesh:
            outs = {}
            for mode in ("local", "push", "pull"):
                f = jax.jit(lambda p, x: moe_block(p, x, experts_per_token=2, comm_mode=mode))
                outs[mode] = np.asarray(f(params, x))
            e1 = np.max(np.abs(outs["push"] - outs["local"]))
            e2 = np.max(np.abs(outs["pull"] - outs["local"]))
            assert e1 < 1e-4 and e2 < 1e-4, (e1, e2)
            # the collective schedules must actually differ
            hp = jax.jit(lambda p, x: moe_block(p, x, experts_per_token=2, comm_mode="push")).lower(params, x).compile().as_text()
            hl = jax.jit(lambda p, x: moe_block(p, x, experts_per_token=2, comm_mode="pull")).lower(params, x).compile().as_text()
            assert "all-to-all" in hp
            assert "all-gather" in hl
        print("moe ok", float(e1), float(e2))
    """)
    assert "moe ok" in out


def test_compressed_psum_accuracy():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.compress import compressed_psum_mean
        mesh = jax.make_mesh((8,), ("pod",))
        x = jax.random.normal(jax.random.key(0), (10000,), jnp.float32)
        with mesh:
            got = compressed_psum_mean(x, "pod", mesh)
        # all shards hold the same x → mean == x, up to int8 quantisation
        rel = float(jnp.max(jnp.abs(got - x)) / jnp.max(jnp.abs(x)))
        assert rel < 0.02, rel
        print("compress ok", rel)
    """)
    assert "compress ok" in out


def test_train_step_runs_sharded():
    """A real sharded train step on a (4, 2) mesh: loss finite, params move."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models import sharding as shd
        from repro.models.partitioning import param_shardings
        from repro.train.train_step import TrainConfig, make_train_step, init_all
        from repro.train.optimizer import AdamWConfig
        cfg = smoke_config("qwen3-moe-30b-a3b")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        tc = TrainConfig(adamw=AdamWConfig(learning_rate=1e-3))
        with shd.activate(mesh), mesh:
            params, opt = init_all(cfg, tc, jax.random.key(0))
            params = jax.device_put(params, param_shardings(cfg, params, mesh))
            step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
            toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 32)))
            l0 = None
            for i in range(6):
                params, opt, m = step(params, opt, {"tokens": toks})
                l0 = l0 or float(m["loss"])
            assert float(m["loss"]) < l0
        print("sharded train ok", l0, float(m["loss"]))
    """)
    assert "sharded train ok" in out


def test_elastic_reshard_8_to_4(tmp_path):
    d = str(tmp_path / "ck")
    run_py(f"""
        import jax
        from repro.configs import smoke_config
        from repro.train.train_step import TrainConfig, init_all
        from repro.train import checkpoint as ckpt
        cfg = smoke_config("granite-3-8b")
        tc = TrainConfig()
        params, opt = init_all(cfg, tc, jax.random.key(0))
        ckpt.save({d!r}, 3, params, opt)
        print("saved on", len(jax.devices()))
    """)
    # reload on a DIFFERENT device count (4) and keep training
    env4 = dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=4")
    r = subprocess.run([sys.executable, "-u", "-c", textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models import sharding as shd
        from repro.train.elastic import make_mesh_from_available, reshard_checkpoint
        from repro.train.train_step import TrainConfig, make_train_step
        cfg = smoke_config("granite-3-8b")
        tc = TrainConfig()
        mesh = make_mesh_from_available(model_axis=2)
        with shd.activate(mesh), mesh:
            params, opt, _ = reshard_checkpoint({d!r}, 3, cfg, tc, mesh)
            step = jax.jit(make_train_step(cfg, tc))
            toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 16)))
            params, opt, m = step(params, opt, {{"tokens": toks}})
            assert bool(jnp.isfinite(m["loss"]))
        print("elastic ok", len(jax.devices()))
    """)], env=env4, cwd="/root/repo", capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "elastic ok 4" in r.stdout


def test_hlo_counter_counts_collectives_in_loops():
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_counter import analyze
        mesh = jax.make_mesh((8,), ("data",))
        def f(x, w):
            def body(c, _):
                y = jax.lax.with_sharding_constraint(c @ w, NamedSharding(mesh, P(None, None)))
                return y, None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return jnp.sum(y)
        xs = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        with mesh:
            c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "data")), NamedSharding(mesh, P("data", None)))).lower(xs, ws).compile()
        cnt = analyze(c.as_text())
        # counts are PER DEVICE: the matmul is contraction-sharded 8 ways
        expect = 7 * 2 * 128 * 256 * 256 / 8
        assert abs(cnt.flops - expect) / expect < 0.01, cnt.flops
        assert cnt.coll_calls.get("all-reduce", 0) >= 7
        print("counter ok", cnt.flops, cnt.coll)
    """)
    assert "counter ok" in out
