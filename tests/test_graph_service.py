"""Multi-tenant graph service lifecycle tests (serve/graph_service.py).

Covers: admission/rejection at capacity, slot free-and-reuse after query
completion, per-tenant match-budget enforcement, cooperative scheduler ticks,
flow merging, and the 3-tenant mixed-query correctness check — each tenant's
concurrent count must equal both an isolated single-query run and the
networkx oracle (the acceptance bar for subgraph-matching-as-a-service)."""
import numpy as np
import pytest

from repro.core.dataflow import merge_flows
from repro.core.engine import EngineConfig, HugeEngine, enumerate_query, flow_queue_cells
from repro.core.query import PAPER_QUERIES, triangle
from repro.core.scheduler import AdaptiveScheduler
from repro.graph import powerlaw_graph
from repro.graph.oracle import count_instances
from repro.serve.graph_service import (
    BUDGET_EXCEEDED,
    DONE,
    QUEUED,
    REJECTED,
    RUNNING,
    GraphQueryRequest,
    GraphService,
    ServiceConfig,
    TenantBudget,
)


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(256, 5.0, seed=3)


@pytest.fixture(scope="module")
def oracle(graph):
    def _oracle(q):
        return count_instances(graph, list(q.edges))
    return _oracle


def small_cfg(**kw) -> ServiceConfig:
    base = dict(queue_capacity=1 << 10, join_buffer_capacity=1 << 12,
                tick_steps=16, max_active=4)
    base.update(kw)
    return ServiceConfig(**base)


# ---------------------------------------------------------------------------
# scheduler tick budget (the cooperative-yield primitive the service runs on)
# ---------------------------------------------------------------------------

class _TickOp:
    def __init__(self, n):
        self.label = "op"
        self.inbox = n
        self.runs = 0

    def has_input(self):
        return self.inbox > 0

    def output_free(self):
        return 1 << 30

    def required_slack(self):
        return 1

    def run_one(self):
        self.inbox -= 1
        self.runs += 1


def test_scheduler_max_steps_budget_and_resume():
    op = _TickOp(10)
    st = AdaptiveScheduler([op]).run(max_steps=3)
    assert st.steps == 3 and not st.completed and op.inbox == 7
    # a fresh pass over the same runtimes resumes where the queues left off
    st2 = AdaptiveScheduler([op]).run()
    assert st2.completed and op.inbox == 0 and op.runs == 10


# ---------------------------------------------------------------------------
# admission / rejection
# ---------------------------------------------------------------------------

def test_admission_queue_rejects_at_capacity(graph):
    svc = GraphService(graph, small_cfg(admission_queue_len=2))
    t1 = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    t2 = svc.submit(GraphQueryRequest(tenant="b", query="q1"))
    t3 = svc.submit(GraphQueryRequest(tenant="c", query="q1"))
    assert t1.status == QUEUED and t2.status == QUEUED
    assert t3.status == REJECTED and "admission queue full" in t3.error
    svc.run_until_idle()
    assert t1.status == DONE and t2.status == DONE
    assert t3.status == REJECTED  # rejection is final


def test_tenant_inflight_cap_rejects(graph):
    svc = GraphService(
        graph, small_cfg(), tenants={"a": TenantBudget(max_inflight=1)}
    )
    t1 = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    t2 = svc.submit(GraphQueryRequest(tenant="a", query="q2"))
    other = svc.submit(GraphQueryRequest(tenant="b", query="q2"))
    assert t1.status == QUEUED
    assert t2.status == REJECTED and "max_inflight" in t2.error
    assert other.status == QUEUED  # caps are per tenant, not global
    svc.run_until_idle()
    assert t1.status == DONE and other.status == DONE
    # inflight released on completion: the same tenant may submit again
    t4 = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    assert t4.status == QUEUED
    svc.run_until_idle()
    assert t4.status == DONE and t4.count == t1.count


def test_unknown_query_rejected(graph):
    svc = GraphService(graph, small_cfg())
    t = svc.submit(GraphQueryRequest(tenant="a", query="not-a-query"))
    assert t.status == REJECTED and "unknown query" in t.error


def test_oversized_query_rejected_not_queued_forever(graph):
    # A query whose slot-slice exceeds the whole pool can never be admitted:
    # it must be rejected at admission, not starve the queue.
    svc = GraphService(graph, small_cfg(total_queue_cells=1000))
    t = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    assert t.status == QUEUED
    svc.tick()
    assert t.status == REJECTED and "service pool" in t.error


# ---------------------------------------------------------------------------
# slot accounting: lease, free, reuse
# ---------------------------------------------------------------------------

def _q1_cells(graph, cfg: ServiceConfig) -> int:
    eng = HugeEngine(graph, EngineConfig())
    flow = eng.to_flow(PAPER_QUERIES["q1"])
    return flow_queue_cells(flow, eng.cfg, eng.d_pad,
                            cfg.queue_capacity, cfg.join_buffer_capacity)


def test_pool_fits_one_query_at_a_time(graph):
    cells = _q1_cells(graph, small_cfg())
    # Pool sized for exactly one q1 session: the second request must wait.
    svc = GraphService(graph, small_cfg(total_queue_cells=int(cells * 1.5)))
    t1 = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    t2 = svc.submit(GraphQueryRequest(tenant="b", query="q1"))
    svc.tick()
    assert t1.status == RUNNING and t2.status == QUEUED
    assert svc.pool.leased_cells == cells
    svc.run_until_idle()
    # both completed — t2 got t1's freed slots — and every lease was returned
    assert t1.status == DONE and t2.status == DONE
    assert t2.admitted_at >= t1.finished_at  # strictly after the slot freed
    assert svc.pool.leased_cells == 0
    assert svc.tenant_usage("a") == {"inflight": 0, "queue_cells": 0}
    assert svc.tenant_usage("b") == {"inflight": 0, "queue_cells": 0}


def test_tenant_cell_cap_serialises_that_tenant_only(graph):
    cells = _q1_cells(graph, small_cfg())
    svc = GraphService(
        graph, small_cfg(),
        tenants={"a": TenantBudget(max_queue_cells=int(cells * 1.5))},
    )
    a1 = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    a2 = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    b1 = svc.submit(GraphQueryRequest(tenant="b", query="q1"))
    svc.tick()
    # a2 waits on tenant a's cap; b is unaffected (isolation)
    assert a1.status == RUNNING and a2.status == QUEUED and b1.status == RUNNING
    svc.run_until_idle()
    assert a1.status == DONE and a2.status == DONE and b1.status == DONE
    assert a1.count == a2.count == b1.count


# ---------------------------------------------------------------------------
# per-tenant match budgets
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_graph():
    # Dense enough that triangle results far exceed one slot-slice queue, so
    # the sink drains incrementally and budget enforcement can interrupt a
    # query mid-flight (budget checks are batch-granular by design).
    return powerlaw_graph(512, 10.0, seed=5)


def budget_cfg() -> ServiceConfig:
    return small_cfg(queue_capacity=256, tick_steps=2)


def test_match_budget_stops_query_early(dense_graph):
    tri = triangle()
    total = count_instances(dense_graph, list(tri.edges))
    assert total > 500, "fixture graph too sparse for the budget test"
    svc = GraphService(dense_graph, budget_cfg())
    t = svc.submit(GraphQueryRequest(tenant="a", query=tri, match_budget=10))
    svc.run_until_idle()
    assert t.status == BUDGET_EXCEEDED
    assert 10 <= t.count < total  # crossed the budget, stopped before the end
    assert svc.pool.leased_cells == 0  # budget-stopped queries free their slots too


def test_tenant_default_match_budget_applies(dense_graph):
    tri = triangle()
    total = count_instances(dense_graph, list(tri.edges))
    svc = GraphService(
        dense_graph, budget_cfg(),
        tenants={"capped": TenantBudget(max_matches=10)},
    )
    t = svc.submit(GraphQueryRequest(tenant="capped", query=tri))
    u = svc.submit(GraphQueryRequest(tenant="free", query=tri))
    svc.run_until_idle()
    assert t.status == BUDGET_EXCEEDED and t.count < total
    assert u.status == DONE and u.count == total


# ---------------------------------------------------------------------------
# correctness: concurrent == isolated == oracle
# ---------------------------------------------------------------------------

def test_three_tenant_mixed_queries_match_oracle(graph, oracle):
    # tick_steps=1 keeps the first tick far too small to finish any query, so
    # the concurrency assertion below is deterministic.
    svc = GraphService(graph, small_cfg(tick_steps=1, max_active=3))
    mix = [("alice", "q1"), ("bob", "q2"), ("carol", "q3")]
    tickets = [
        svc.submit(GraphQueryRequest(tenant=t, query=q)) for t, q in mix
    ]
    svc.tick()
    assert all(t.status == RUNNING for t in tickets)  # truly concurrent
    svc.run_until_idle()
    for ticket, (_, qname) in zip(tickets, mix):
        q = PAPER_QUERIES[qname]
        isolated = enumerate_query(graph, q).count
        assert ticket.status == DONE
        assert ticket.count == isolated == oracle(q), (qname, ticket.count)
        assert ticket.latency_s is not None and ticket.latency_s > 0
        assert ticket.stats.batches > 0  # per-tenant stats were attributed


def test_latency_is_per_request_not_per_service(graph):
    # Two sequentially-admitted queries: the second's queue wait is visible
    # in its latency, but its *service* time starts at its own admission —
    # the first query's wall time is reflected only through the wait.
    svc = GraphService(graph, small_cfg(max_active=1))
    t1 = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    t2 = svc.submit(GraphQueryRequest(tenant="b", query="q1"))
    svc.run_until_idle()
    assert t1.queue_wait_s is not None and t2.queue_wait_s is not None
    assert t2.queue_wait_s >= (t1.finished_at - t2.submitted_at) - 1e-6
    assert t2.latency_s >= t2.queue_wait_s


# ---------------------------------------------------------------------------
# flow merging (the mixed-traffic substrate shared with distributed.py)
# ---------------------------------------------------------------------------

def test_merge_flows_reindexes_and_keeps_sinks(graph):
    eng = HugeEngine(graph, EngineConfig())
    f1 = eng.to_flow(PAPER_QUERIES["q1"])
    f2 = eng.to_flow(PAPER_QUERIES["q3"])
    merged, tenant_of_op = merge_flows([f1, f2])
    assert len(merged.ops) == len(f1.ops) + len(f2.ops)
    assert merged.sink_indices() == (len(f1.ops) - 1, len(merged.ops) - 1)
    assert tenant_of_op == tuple([0] * len(f1.ops) + [1] * len(f2.ops))
    off = len(f1.ops)
    for i, op in enumerate(merged.ops[off:]):
        assert op.inputs == tuple(j + off for j in f2.ops[i].inputs)
    # pricing is additive over a merge (no shared queues between tenants)
    cells = flow_queue_cells(merged, eng.cfg, eng.d_pad)
    assert cells == (
        flow_queue_cells(f1, eng.cfg, eng.d_pad)
        + flow_queue_cells(f2, eng.cfg, eng.d_pad)
    )
