"""End-to-end behaviour tests: the full HUGE pipeline against the VF2 oracle."""
import pytest

from repro.core import query as Q
from repro.core.engine import EngineConfig, HugeEngine
from repro.graph import erdos_renyi, powerlaw_graph, ring_of_cliques, grid_graph
from repro.graph.oracle import count_instances


def _cfg(**kw):
    base = dict(batch_size=128, queue_capacity=1 << 14, cache_capacity=1 << 10,
                num_machines=4)
    base.update(kw)
    return EngineConfig(**base)


GRAPHS = {
    "er": lambda: erdos_renyi(150, 6.0, seed=1),
    "powerlaw": lambda: powerlaw_graph(200, 6.0, seed=2),
    "cliques": lambda: ring_of_cliques(8, 5),
    "grid": lambda: grid_graph(12, 12),
}


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("qname", ["q1", "q2", "q3", "q7"])
def test_counts_match_oracle(gname, qname):
    graph = GRAPHS[gname]()
    query = Q.PAPER_QUERIES[qname]
    res = HugeEngine(graph, _cfg()).run(query)
    assert res.count == count_instances(graph, list(query.edges))


@pytest.mark.parametrize("qname", ["q4", "q5", "q6", "q8"])
def test_larger_queries(qname):
    graph = erdos_renyi(120, 7.0, seed=3)
    query = Q.PAPER_QUERIES[qname]
    res = HugeEngine(graph, _cfg()).run(query)
    assert res.count == count_instances(graph, list(query.edges))


@pytest.mark.parametrize("space", ["huge", "bigjoin", "benu", "rads", "seed", "starjoin"])
def test_all_plan_spaces_agree(space):
    """Every Table-2 plan space must produce identical counts (Remark 3.2)."""
    graph = erdos_renyi(120, 6.0, seed=4)
    query = Q.PAPER_QUERIES["q1"]
    res = HugeEngine(graph, _cfg()).run(query, space=space)
    assert res.count == count_instances(graph, list(query.edges))


def test_matches_materialised_exactly():
    """Not just the count: the actual match set equals brute force."""
    from repro.graph.oracle import enumerate_instances_bruteforce

    graph = erdos_renyi(60, 5.0, seed=5)
    query = Q.triangle()
    res = HugeEngine(graph, _cfg(materialize=True)).run(query)
    got = set()
    if res.matches is not None:
        for row in res.matches:
            got.add(frozenset(int(x) for x in row))
    want = enumerate_instances_bruteforce(graph, list(query.edges))
    assert got == want


def test_memory_stays_bounded():
    """Peak queue fill never exceeds capacity + one batch's worst case
    (Theorem 5.4 made structural)."""
    graph = powerlaw_graph(300, 8.0, seed=6)
    cfg = _cfg(queue_capacity=1 << 12, batch_size=128)
    eng = HugeEngine(graph, cfg)
    res = eng.run(Q.PAPER_QUERIES["q1"])
    d_pad = graph.padded.d_pad
    per_queue_cap = cfg.queue_capacity + cfg.batch_size * d_pad
    assert res.stats.peak_queue_rows <= 4 * per_queue_cap  # ≤ #ops × cap
    assert res.count == count_instances(graph, [(0, 1), (1, 2), (2, 3), (3, 0)])


def test_dfs_vs_bfs_same_count():
    graph = erdos_renyi(150, 6.0, seed=7)
    query = Q.PAPER_QUERIES["q2"]
    small = HugeEngine(graph, _cfg(queue_capacity=1 << 10)).run(query)
    big = HugeEngine(graph, _cfg(queue_capacity=1 << 18)).run(query)
    assert small.count == big.count


def test_cache_policies_do_not_change_results():
    graph = powerlaw_graph(200, 6.0, seed=8)
    query = Q.PAPER_QUERIES["q1"]
    counts = set()
    for policy in ("lrbu", "lru", "direct"):
        counts.add(HugeEngine(graph, _cfg(cache_policy=policy)).run(query).count)
    counts.add(HugeEngine(graph, _cfg(cache_capacity=0)).run(query).count)
    assert len(counts) == 1


def test_intersect_kernel_path_agrees():
    """use_intersect_kernel=True (Pallas interpret path) gives identical counts."""
    graph = erdos_renyi(100, 5.0, seed=9)
    query = Q.PAPER_QUERIES["q2"]
    a = HugeEngine(graph, _cfg()).run(query)
    b = HugeEngine(graph, _cfg(use_intersect_kernel=True)).run(query)
    assert a.count == b.count
