"""Paper §6 applications: path queries on the HUGE operators vs networkx."""
import networkx as nx
import pytest

from repro.core.paths import hop_constrained_paths, shortest_path_length
from repro.graph import erdos_renyi, grid_graph
from repro.graph.storage import to_networkx


@pytest.mark.parametrize("gname", ["er", "grid"])
def test_shortest_path_matches_networkx(gname):
    graph = erdos_renyi(120, 5.0, seed=3) if gname == "er" else grid_graph(8, 8)
    g = to_networkx(graph)
    pairs = [(0, graph.num_vertices - 1), (1, graph.num_vertices // 2), (2, 7)]
    for s, t in pairs:
        try:
            want = nx.shortest_path_length(g, s, t)
        except nx.NetworkXNoPath:
            want = None
        got = shortest_path_length(graph, s, t)
        assert got == want, (s, t, got, want)


def test_hop_constrained_paths_match_bruteforce():
    graph = erdos_renyi(40, 4.0, seed=5)
    g = to_networkx(graph)
    s, t, hops = 0, 5, 4
    want = {
        tuple(p) for p in nx.all_simple_paths(g, s, t, cutoff=hops) if len(p) == hops + 1
    }
    got = set(hop_constrained_paths(graph, s, t, hops))
    assert got == want


def test_hop_constrained_odd_hops():
    graph = grid_graph(5, 5)
    g = to_networkx(graph)
    s, t, hops = 0, 6, 3
    want = {
        tuple(p) for p in nx.all_simple_paths(g, s, t, cutoff=hops) if len(p) == hops + 1
    }
    got = set(hop_constrained_paths(graph, s, t, hops))
    assert got == want
