"""Training substrate: convergence, checkpoint fault tolerance, restart
determinism, adaptive microbatching, optimizer math."""
import glob
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.adaptive_schedule import choose_microbatches, estimate_activation_bytes
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, PrefetchLoader, synth_batch
from repro.train.optimizer import AdamWConfig, apply_updates, init_state
from repro.train.train_step import TrainConfig, init_all, make_train_step


def test_loss_decreases():
    cfg = smoke_config("granite-3-8b")
    tc = TrainConfig(adamw=AdamWConfig(learning_rate=1e-3, warmup_steps=2, total_steps=40))
    params, opt = init_all(cfg, tc, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=1)
    losses = []
    for i in range(12):
        b = synth_batch(dc, i)
        params, opt, m = step(params, opt, {"tokens": jnp.asarray(b["tokens"])})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1


def test_microbatched_grads_match_full_batch():
    cfg = smoke_config("granite-3-8b")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8, seed=2)
    b = synth_batch(dc, 0)["tokens"]
    tc1 = TrainConfig(adamw=AdamWConfig(learning_rate=0.0, weight_decay=0.0))
    tc4 = TrainConfig(adamw=AdamWConfig(learning_rate=0.0, weight_decay=0.0), microbatches=4)
    params, opt = init_all(cfg, tc1, jax.random.key(3))
    # lr=0 → params unchanged; compare losses from both paths
    _, _, m1 = make_train_step(cfg, tc1)(params, opt, {"tokens": jnp.asarray(b)})
    b4 = b.reshape(4, 2, 16)
    _, _, m4 = make_train_step(cfg, tc4)(params, init_state(tc4.adamw, params), {"tokens": jnp.asarray(b4)})
    # microbatch loss is the mean over equal-size microbatches == full loss
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2


def test_checkpoint_roundtrip_and_corruption(tmp_path):
    cfg = smoke_config("chatglm3-6b")
    tc = TrainConfig()
    params, opt = init_all(cfg, tc, jax.random.key(0))
    d = str(tmp_path)
    ckpt.save(d, 7, params, opt)
    assert ckpt.latest_step(d) == 7
    p2, o2, _ = ckpt.load(d, 7, params, opt)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.dtype == b.dtype
        assert bool(jnp.all(a == b))
    # corrupt → rejected; older valid checkpoint wins
    ckpt.save(d, 3, params, opt)
    npz = os.path.join(d, "step_00000007", "arrays.npz")
    size = os.path.getsize(npz)
    with open(npz, "r+b") as f:  # hit actual array payload, not zip padding
        f.seek(size // 2)
        f.write(b"CORRUPTCORRUPT!!")
    assert ckpt.latest_step(d) == 3


def test_adamw_step_math():
    cfg = AdamWConfig(learning_rate=0.1, beta1=0.0, beta2=0.0, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=1, min_lr_ratio=1.0)
    params = {"w": jnp.ones((2, 2), jnp.float32)}
    state = init_state(cfg, params)
    grads = {"w": jnp.full((2, 2), 0.5, jnp.float32)}
    new_p, new_s, m = apply_updates(cfg, params, state, grads)
    # beta1=beta2=0: m=g, v=g² → delta = g/|g| = 1 → p' = 1 - 0.1
    np.testing.assert_allclose(np.asarray(new_p["w"]), 0.9, rtol=1e-4)
    assert int(new_s["step"]) == 1


def test_adamw_bf16_state_dtype():
    cfg = AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init_state(cfg, params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    _, new_s, _ = apply_updates(cfg, params, state, {"w": jnp.ones((4,), jnp.bfloat16)})
    assert new_s["v"]["w"].dtype == jnp.bfloat16


def test_adaptive_microbatching_monotone():
    cfg = smoke_config("granite-3-8b").scaled(num_layers=4, d_model=256, d_ff=512)
    tight = choose_microbatches(cfg, 64, 512, device_count=1, budget_bytes=1 << 20)
    loose = choose_microbatches(cfg, 64, 512, device_count=1, budget_bytes=1 << 40)
    assert loose.num_microbatches == 1          # BFS when memory allows
    assert tight.num_microbatches > loose.num_microbatches  # DFS under pressure
    assert estimate_activation_bytes(cfg, 1024) < estimate_activation_bytes(cfg, 4096)


def test_data_pipeline_deterministic_and_prefetching():
    dc = DataConfig(vocab_size=100, seq_len=8, global_batch=4, seed=9)
    a = synth_batch(dc, 5)["tokens"]
    b = synth_batch(dc, 5)["tokens"]
    np.testing.assert_array_equal(a, b)
    loader = PrefetchLoader(dc)
    x1 = next(loader)
    x2 = next(loader)
    assert not np.array_equal(x1["tokens"], x2["tokens"])
    loader.close()


def test_train_driver_restart_resumes(tmp_path):
    """Integration: crash injection + restart via the real driver CLI."""
    env = dict(os.environ, PYTHONPATH="src")
    d = str(tmp_path / "ck")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "granite-3-8b",
           "--smoke", "--steps", "16", "--ckpt-dir", d, "--ckpt-every", "2",
           "--global-batch", "4", "--seq-len", "16", "--log-every", "5"]
    r1 = subprocess.run(cmd + ["--fail-at", "12"], env=env, cwd="/root/repo",
                        capture_output=True, text=True, timeout=480)
    assert "injected failure" in r1.stdout
    # at least one async checkpoint (every 2 steps, crash at 12) completed
    assert ckpt.latest_step(d) is not None
    r2 = subprocess.run(cmd, env=env, cwd="/root/repo",
                        capture_output=True, text=True, timeout=480)
    assert "resuming from valid checkpoint step" in r2.stdout
    assert "done" in r2.stdout
