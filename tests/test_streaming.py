"""Streaming / incremental enumeration tests (DESIGN.md §Delta-plans).

Covers the graph-storage edge cases that mutating workloads trip
(``has_edge`` ranks, ``d_pad`` lane rounding, incremental ``apply_updates``
vs. a full rebuild) and the delta-plan decomposition end to end: per-batch
``run_delta`` counts equal the oracle's match delta on paper queries over
arbitrary splits of a random edge stream, materialised delta rows are
emitted exactly once, the 4-device distributed engine agrees with full
re-enumeration, and standing queries in the service see the same deltas.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataflow import delta_flows, merge_flows
from repro.core.engine import EngineConfig, HugeEngine
from repro.core.query import PAPER_QUERIES
from repro.graph import build_graph, powerlaw_graph
from repro.graph.oracle import count_instances
from repro.graph.storage import (
    _LANE,
    INVALID,
    GraphUpdateBatch,
    PaddedAdjacency,
    apply_updates,
)


def random_edge_stream(n, m, seed):
    """A simple random graph as a shuffled undirected edge array."""
    rng = np.random.default_rng(seed)
    und = set()
    while len(und) < m:
        a, b = rng.integers(0, n, 2)
        if a != b:
            und.add((min(a, b), max(a, b)))
    und = np.array(sorted(und))
    rng.shuffle(und)
    return und


# ---------------------------------------------------------------------------
# Storage edge cases (the satellite bug fixes)
# ---------------------------------------------------------------------------

def test_has_edge_scalar_1d_batched():
    g = build_graph(np.array([[0, 1], [1, 2], [2, 3]]), 5)
    # scalar: used to crash (vmap over rank-0 operands)
    assert bool(g.has_edge(0, 1))
    assert bool(g.has_edge(jnp.int32(2), jnp.int32(1)))
    assert not bool(g.has_edge(0, 3))
    assert not bool(g.has_edge(4, 4))
    # 1-D
    u = jnp.array([0, 1, 2, 0, 4])
    v = jnp.array([1, 2, 3, 2, 0])
    assert np.asarray(g.has_edge(u, v)).tolist() == [True, True, True, False, False]
    # 1-D against a scalar broadcasts
    assert np.asarray(g.has_edge(u, jnp.int32(1))).tolist() == [
        True, False, True, True, False]
    # batched 2-D keeps its shape
    ub = u.reshape(1, 5)
    vb = v.reshape(1, 5)
    out = g.has_edge(ub, vb)
    assert out.shape == (1, 5)
    assert np.asarray(out)[0].tolist() == [True, True, True, False, False]


def test_build_graph_rounds_explicit_d_pad():
    g = build_graph(np.array([[0, 1], [1, 2]]), 3, d_pad=3)
    assert g.padded.d_pad == _LANE
    g = build_graph(np.array([[0, 1]]), 2, d_pad=_LANE + 1)
    assert g.padded.d_pad == 2 * _LANE


def test_padded_adjacency_lane_invariant():
    with pytest.raises(ValueError, match="lane"):
        PaddedAdjacency(adj=jnp.full((4, 60), INVALID, jnp.int32),
                        deg=jnp.zeros(4, jnp.int32))
    PaddedAdjacency(adj=jnp.full((4, _LANE), INVALID, jnp.int32),
                    deg=jnp.zeros(4, jnp.int32))  # lane multiple: fine


def test_apply_updates_matches_full_rebuild():
    n = 200
    und = random_edge_stream(n, 700, seed=2)
    base, stream = und[:500], und[500:]
    g = build_graph(base, n)
    applied = apply_updates(g, GraphUpdateBatch(stream))
    full = build_graph(und, n)
    np.testing.assert_array_equal(np.asarray(applied.graph.offsets),
                                  np.asarray(full.offsets))
    np.testing.assert_array_equal(np.asarray(applied.graph.nbrs),
                                  np.asarray(full.nbrs))
    np.testing.assert_array_equal(np.asarray(applied.graph.padded.deg),
                                  np.asarray(full.padded.deg))
    # padded rows agree wherever both exist (d_pad may differ)
    w = min(applied.graph.padded.d_pad, full.padded.d_pad)
    np.testing.assert_array_equal(np.asarray(applied.graph.padded.adj)[:, :w],
                                  np.asarray(full.padded.adj)[:, :w])
    # the delta holds exactly the genuinely-new edges
    assert applied.num_new_edges == stream.shape[0]
    assert applied.delta.num_edges == stream.shape[0]
    # re-applying the same batch is a no-op
    again = apply_updates(applied.graph, GraphUpdateBatch(stream))
    assert again.num_new_edges == 0
    assert again.graph is applied.graph


def test_apply_updates_grows_d_pad_by_lanes():
    n = 300
    g = build_graph(np.array([[0, 1]]), n)
    assert g.padded.d_pad == _LANE
    # a star that overflows one row far past a lane boundary
    star = np.stack([np.zeros(n - 1, np.int64), np.arange(1, n)], axis=1)
    applied = apply_updates(g, GraphUpdateBatch(star))
    assert applied.graph.padded.d_pad % _LANE == 0
    assert applied.graph.padded.d_pad >= n - 1
    assert int(applied.graph.degree(jnp.int32(0))) == n - 1
    # untouched rows still end in INVALID padding
    row5 = np.asarray(applied.graph.padded.adj)[5]
    assert row5[1] == INVALID


def test_apply_updates_rejects_out_of_range():
    g = build_graph(np.array([[0, 1]]), 3)
    with pytest.raises(ValueError, match="outside"):
        apply_updates(g, GraphUpdateBatch(np.array([[0, 7]])))


# ---------------------------------------------------------------------------
# Delta flows: structure and single-process execution
# ---------------------------------------------------------------------------

def test_delta_flows_shape_and_empty_batch():
    from repro.core.cost import GraphStats
    from repro.core.optimizer import optimal_plan

    stats = GraphStats.synthetic(1 << 10, 6.0)
    for qname in ("q1", "q2", "q3"):
        q = PAPER_QUERIES[qname]
        plan = optimal_plan(q, stats, 8, "huge")
        flows = delta_flows(plan)
        assert len(flows) == len(q.edges)  # one flow per query edge
        for i, f in enumerate(flows):
            scans = [op for op in f.ops if op.kind == "scan"]
            assert len(scans) == 1 and scans[0].scan_epoch == "delta"
            olds = sum(ep == "old" for op in f.ops for ep in op.ext_epochs)
            news = sum(ep == "new" for op in f.ops for ep in op.ext_epochs)
            # flow i probes exactly i old edges and k-1-i new ones
            assert olds == i and news == len(q.edges) - 1 - i
        merged, _ = merge_flows(flows)
        assert len(merged.sink_indices()) == len(flows)
        # the merged decomposition passes the static verifier (epoch rules on)
        from repro.analysis.flowcheck import verify_flow
        verify_flow(merged)
        assert delta_flows(plan, GraphUpdateBatch(np.zeros((0, 2), np.int64))) == []


def test_run_delta_counts_match_oracle_diff():
    n = 300
    g_full = powerlaw_graph(n, 5.0, seed=3)
    offs = np.asarray(g_full.offsets)
    nb = np.asarray(g_full.nbrs)
    src = np.repeat(np.arange(n), np.diff(offs))
    und = np.stack([src, nb], 1)
    und = und[und[:, 0] < und[:, 1]]
    rng = np.random.default_rng(3)
    und = und[rng.permutation(len(und))]
    k = int(0.8 * len(und))
    base, stream = und[:k], und[k:]

    cfg = EngineConfig(batch_size=128, materialize=False)
    for qname in ("q1", "q2", "q3"):
        q = PAPER_QUERIES[qname]
        g0 = build_graph(base, n)
        eng = HugeEngine(g0, cfg)
        c_before = count_instances(g0, list(q.edges))
        total = 0
        for chunk in np.array_split(stream, 3):
            eng.apply_updates(GraphUpdateBatch(chunk))
            total += eng.run_delta(q).count
        c_after = count_instances(eng.graph, list(q.edges))
        assert total == c_after - c_before, (qname, total, c_after - c_before)


def test_run_delta_exactly_once_materialised():
    """Every new match appears exactly once across batches — compared as row
    tuples against the engine's own full enumeration before/after, which
    (unlike the vertex-set oracle) preserves the multiplicity of distinct
    embeddings sharing a vertex set."""
    n = 120
    und = random_edge_stream(n, 500, seed=11)
    base, stream = und[:400], und[400:]
    cfg = EngineConfig(batch_size=128, materialize=True)

    def full_rows(graph, q):
        r = HugeEngine(graph, cfg).run(q)
        return set(map(tuple, r.matches)) if r.matches is not None else set()

    for qname in ("q1", "q2", "q3"):
        q = PAPER_QUERIES[qname]
        g0 = build_graph(base, n)
        before = full_rows(g0, q)
        eng = HugeEngine(g0, cfg)
        got = []
        for chunk in np.array_split(stream, 4):
            eng.apply_updates(GraphUpdateBatch(chunk))
            r = eng.run_delta(q)
            if r.matches is not None:
                got.extend(map(tuple, r.matches))
        after = full_rows(eng.graph, q)
        assert len(got) == len(set(got)), f"{qname}: duplicate emission"
        assert set(got) == after - before, f"{qname}: wrong delta set"


def test_run_delta_requires_armed_delta():
    g = build_graph(np.array([[0, 1], [1, 2]]), 3)
    eng = HugeEngine(g, EngineConfig(batch_size=32))
    with pytest.raises(RuntimeError, match="apply_updates"):
        eng.run_delta(PAPER_QUERIES["q1"])


# ---------------------------------------------------------------------------
# Distributed (4 host devices, fresh interpreter) and service
# ---------------------------------------------------------------------------

def test_distributed_run_delta_matches_full_diff():
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    code = textwrap.dedent("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.graph import build_graph
        from repro.graph.storage import GraphUpdateBatch
        from repro.core.distributed import DistributedEngine, DistConfig
        from repro.core.query import PAPER_QUERIES

        rng = np.random.default_rng(5)
        n = 200; m = 700
        und = set()
        while len(und) < m:
            a, b = rng.integers(0, n, 2)
            if a != b: und.add((min(a, b), max(a, b)))
        und = np.array(sorted(und)); rng.shuffle(und)
        base, stream = und[:550], und[550:]

        mesh = Mesh(np.array(jax.devices()[:4]), ("shards",))
        for qname in ("q1", "q2"):
            q = PAPER_QUERIES[qname]
            g0 = build_graph(base, n)
            eng = DistributedEngine(g0, mesh, DistConfig(batch_size=128))
            c0, _ = eng.run(q)
            total = 0
            for chunk in np.array_split(stream, 3):
                eng.apply_updates(GraphUpdateBatch(chunk))
                c, _ = eng.run_delta(q)
                total += c
            c1, _ = DistributedEngine(eng.graph, mesh,
                                      DistConfig(batch_size=128)).run(q)
            assert total == c1 - c0, (qname, total, c1 - c0)
            print(qname, "ok", total)
    """)
    r = subprocess.run([sys.executable, "-u", "-c", code], env=env,
                       cwd="/root/repo", capture_output=True, text=True,
                       timeout=540)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert r.stdout.count("ok") == 2


def test_service_standing_queries_see_deltas():
    from repro.serve.graph_service import (
        GraphQueryRequest,
        GraphService,
        ServiceConfig,
    )

    n = 150
    und = random_edge_stream(n, 600, seed=9)
    base, stream = und[:480], und[480:]
    g0 = build_graph(base, n)
    svc = GraphService(g0, ServiceConfig(), EngineConfig(batch_size=128))
    sq1 = svc.register_standing("alice", "q1")
    sq2 = svc.register_standing("bob", "q2")

    # an ad-hoc query coexists with standing ones
    t = svc.submit(GraphQueryRequest(tenant="carol", query="q1"))
    svc.run_until_idle()
    assert t.status == "done"

    total1 = total2 = 0
    for chunk in np.array_split(stream, 3):
        out = svc.apply_batch(GraphUpdateBatch(chunk))
        assert out["new_edges"] == chunk.shape[0]
        total1 += out["deltas"][sq1.id]
        total2 += out["deltas"][sq2.id]

    cfg = EngineConfig(batch_size=128)
    gN = svc.engine.graph
    for q, total in ((PAPER_QUERIES["q1"], total1), (PAPER_QUERIES["q2"], total2)):
        before = HugeEngine(g0, cfg).run(q).count
        after = HugeEngine(gN, cfg).run(q).count
        assert total == after - before, (q.name, total, after - before)
    assert sq1.total_count == total1 and sq2.total_count == total2
    assert len(sq1.history) == 3
    assert svc.unregister_standing(sq2)
    out = svc.apply_batch(GraphUpdateBatch(und[:2]))  # already present: no-op
    assert out["new_edges"] == 0 and out["deltas"] == {sq1.id: 0}
