"""Property-based tests (hypothesis) on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import cache as lrbu
from repro.core import query as Q
from repro.core.cost import CardinalityEstimator, GraphStats
from repro.core.engine import EngineConfig, HugeEngine
from repro.core.query import symmetry_break
from repro.graph import from_edge_list
from repro.graph.oracle import count_instances
from repro.graph.storage import INVALID

SLOW = dict(deadline=None, suppress_health_check=list(HealthCheck))


@st.composite
def small_graph(draw):
    n = draw(st.integers(8, 28))
    m = draw(st.integers(n, min(n * 3, n * (n - 1) // 2)))
    edges = set()
    for _ in range(m):
        a = draw(st.integers(0, n - 1))
        b = draw(st.integers(0, n - 1))
        if a != b:
            edges.add((min(a, b), max(a, b)))
    # ensure no isolated-vertex id gaps matter: add a path as a backbone
    for i in range(n - 1):
        edges.add((i, i + 1))
    return n, sorted(edges)


@settings(max_examples=15, **SLOW)
@given(small_graph(), st.sampled_from(["triangle", "q1", "q2", "q3"]))
def test_engine_count_equals_oracle(g, qname):
    n, edges = g
    graph = from_edge_list(edges, n)
    query = Q.PAPER_QUERIES.get(qname) or getattr(Q, qname)()
    if qname == "triangle":
        query = Q.triangle()
    cfg = EngineConfig(batch_size=64, queue_capacity=1 << 12, cache_capacity=256,
                       num_machines=3)
    res = HugeEngine(graph, cfg).run(query)
    assert res.count == count_instances(graph, list(query.edges))


@settings(max_examples=30, **SLOW)
@given(st.sampled_from(["triangle", "square", "diamond", "house", "tailed_triangle"]))
def test_symmetry_breaking_is_exact(qname):
    """#automorphisms of q == #orderings killed by the partial orders: for the
    identity data graph (q itself), the engine must count exactly 1 instance."""
    query = getattr(Q, qname)()
    graph = from_edge_list(list(query.edges), query.num_vertices)
    cfg = EngineConfig(batch_size=32, queue_capacity=1 << 10, cache_capacity=64,
                       num_machines=2)
    res = HugeEngine(graph, cfg).run(query)
    assert res.count == count_instances(graph, list(query.edges))


@settings(max_examples=20, **SLOW)
@given(st.lists(st.integers(0, 5000), min_size=4, max_size=64))
def test_lrbu_hit_after_insert(vids):
    """Any vid inserted in batch t must hit in batch t+1 (LRBU never evicts
    the most recent batch while capacity ≥ batch uniques)."""
    arr = jnp.asarray(np.unique(np.asarray(vids, np.int32)))
    pad = jnp.full((64 - arr.shape[0],), INVALID, jnp.int32)
    batch = jnp.concatenate([arr, pad])
    state = lrbu.make_cache(256, ways=4)
    state, hit1 = lrbu.fetch_update(state, batch)
    state, hit2 = lrbu.fetch_update(state, batch)
    valid = batch != INVALID
    assert bool(jnp.all(hit2[valid])), "second access must hit"


@settings(max_examples=20, **SLOW)
@given(small_graph(), st.sampled_from(["q1", "q2", "q3"]))
def test_estimator_positive_and_finite(g, qname):
    n, edges = g
    graph = from_edge_list(edges, n)
    est = CardinalityEstimator(GraphStats.from_graph(graph))
    v = est.estimate(frozenset(Q.PAPER_QUERIES[qname].edges))
    assert np.isfinite(v) and v >= 1.0


@settings(max_examples=10, **SLOW)
@given(small_graph())
def test_plan_spaces_agree_on_count(g):
    n, edges = g
    graph = from_edge_list(edges, n)
    query = Q.PAPER_QUERIES["q2"]
    cfg = EngineConfig(batch_size=64, queue_capacity=1 << 12, cache_capacity=128,
                       num_machines=2)
    counts = {
        space: HugeEngine(graph, cfg).run(query, space=space).count
        for space in ("huge", "bigjoin", "seed")
    }
    assert len(set(counts.values())) == 1, counts
