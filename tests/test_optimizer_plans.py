"""Optimiser + plan-space tests: Table-2 constraints, Eq. 3, translation."""
import pytest

from repro.core import query as Q
from repro.core.cost import GraphStats
from repro.core.dataflow import translate
from repro.core.optimizer import optimal_plan
from repro.core.plan import (
    PLAN_SPACES,
    is_complete_star_join,
    pull_hash_root,
    star_of,
)

STATS = GraphStats.synthetic(1 << 14, 8.0)


def _walk(node, fn):
    fn(node)
    if not node.is_leaf:
        _walk(node.left, fn)
        _walk(node.right, fn)


@pytest.mark.parametrize("qname", ["q1", "q2", "q3", "q6", "q7", "q8"])
def test_huge_plans_cover_query(qname):
    q = Q.PAPER_QUERIES[qname]
    plan = optimal_plan(q, STATS, 8, "huge")
    assert plan.root.edges == q.edges
    flow = translate(plan)
    assert set(flow.ops[-1].schema) == set(range(q.num_vertices))


def test_bigjoin_space_is_wco_push_leftdeep():
    q = Q.PAPER_QUERIES["q3"]
    plan = optimal_plan(q, STATS, 8, "bigjoin")

    def check(node):
        if not node.is_leaf:
            assert node.algo == "wco" and node.comm == "push"
            assert is_complete_star_join(node.left.edges, node.right.edges) is not None
            assert len(node.right.edges) >= 1

    _walk(plan.root, check)


def test_benu_space_is_wco_pull():
    plan = optimal_plan(Q.PAPER_QUERIES["q1"], STATS, 8, "benu")

    def check(node):
        if not node.is_leaf:
            assert node.algo == "wco" and node.comm == "pull"

    _walk(plan.root, check)


def test_starjoin_space_is_hash_push():
    plan = optimal_plan(Q.PAPER_QUERIES["q1"], STATS, 8, "starjoin")

    def check(node):
        if not node.is_leaf:
            assert node.algo == "hash" and node.comm == "push"
            assert star_of(node.right.edges) is not None  # left-deep: rhs unit

    _walk(plan.root, check)


def test_figure_1b_4clique_is_extend_chain():
    """Paper Example 3.1 / Fig 1b: the optimal 4-clique plan is an edge scan
    followed by two complete-star-join extensions."""
    plan = optimal_plan(Q.clique(4), STATS, 8, "huge")
    flow = translate(plan)
    kinds = [op.kind for op in flow.ops]
    assert kinds == ["scan", "extend", "extend", "sink"]
    assert flow.ops[1].ext and flow.ops[2].ext
    assert len(flow.ops[2].ext) == 3  # final vertex intersects 3 neighbours


def test_figure_1d_5path_uses_push_join():
    """Paper Fig 1d: the 5-path plan joins two 3-paths with a pushing hash
    join — both comm modes in one plan (the hybrid claim)."""
    plan = optimal_plan(Q.path(5), STATS, 8, "huge")
    algos = []

    def collect(node):
        if not node.is_leaf:
            algos.append((node.algo, node.comm))

    _walk(plan.root, collect)
    assert ("hash", "push") in algos


def test_pull_cost_caps_at_graph_size():
    """Remark 3.1: with enormous intermediate results the optimiser must
    prefer pull (k·|E_G|) over pushing them."""
    big_stats = GraphStats.synthetic(1 << 12, 40.0)  # dense → huge wedges
    plan = optimal_plan(Q.PAPER_QUERIES["q1"], big_stats, 4, "huge")
    comms = []

    def collect(node):
        if not node.is_leaf:
            comms.append(node.comm)

    _walk(plan.root, collect)
    assert "pull" in comms


def test_symmetry_break_kills_automorphisms():
    for q in (Q.triangle(), Q.square(), Q.clique(4), Q.path(5)):
        conds = Q.symmetry_break(q)
        auts = q.automorphisms()
        # conditions must leave exactly one representative per automorphism
        # class: the identity must satisfy them under some relabeling; check
        # that applying conds as a filter over all automorphism images of a
        # canonical tuple keeps exactly one.
        base = tuple(range(q.num_vertices))
        kept = 0
        for perm in auts:
            ok = all(perm[a] < perm[b] for a, b in conds)
            kept += ok
        assert kept == 1, (q.name, kept)


def test_complete_star_join_detection():
    left = frozenset({(0, 1)})
    right = frozenset({(0, 2), (1, 2)})  # star root 2, leaves {0,1} ⊆ V(left)
    assert is_complete_star_join(left, right) == (2, frozenset({0, 1}))
    assert pull_hash_root(left, frozenset({(0, 2), (0, 3)})) == (0, frozenset({2, 3}))
    assert is_complete_star_join(left, frozenset({(0, 2), (0, 3)})) is None
