"""Differential harness for the fused hot path (DESIGN.md §Fused-hot-path).

The same optimiser plans run through the fused and unfused engines on random
power-law and clique-heavy graphs; both must agree *exactly* with the
networkx oracle. Counts are integers, so any kernel-semantics divergence —
padding, INVALID handling, order filters, cache addressing — shows up as an
off-by-N, not a tolerance question.

Set ``REPRO_FORCE_KERNEL=1`` to run the fused engine's Pallas kernels in
interpret mode (the CI kernel leg does); by default the fused engine uses the
pure-jnp ref twins, which exercise the same fused dataflow at XLA speed.
"""
import os

import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic tests below still run
    HAVE_HYPOTHESIS = False

from repro.core import query as Q
from repro.core.engine import EngineConfig, HugeEngine
from repro.graph.generators import powerlaw_graph, ring_of_cliques
from repro.graph.oracle import count_instances

FORCE_KERNEL = os.environ.get("REPRO_FORCE_KERNEL", "0") == "1"

# Small capacities keep interpret-mode grids short; batch sizes are chosen to
# produce remainder tiles (B % TILE_B != 0 inside padded kernel dispatch).
_CFG = dict(
    batch_size=32,
    queue_capacity=1 << 12,
    join_buffer_capacity=1 << 11,
    join_out_capacity=1 << 12,
    cache_capacity=128,
    num_machines=3,
)


def _counts(graph, query, space):
    base = HugeEngine(graph, EngineConfig(**_CFG)).run(query, space=space).count
    fused = HugeEngine(
        graph, EngineConfig(**_CFG, fused=True, force_kernel=FORCE_KERNEL)
    ).run(query, space=space).count
    return base, fused


if HAVE_HYPOTHESIS:
    SLOW = dict(deadline=None, suppress_health_check=list(HealthCheck))

    @st.composite
    def powerlaw(draw):
        n = draw(st.integers(16, 48))
        deg = draw(st.floats(2.0, 6.0))
        seed = draw(st.integers(0, 1 << 16))
        return powerlaw_graph(n, deg, seed=seed)

    @st.composite
    def clique_heavy(draw):
        return ring_of_cliques(draw(st.integers(2, 5)), draw(st.integers(3, 6)))

    @settings(max_examples=3 if FORCE_KERNEL else 12, **SLOW)
    @given(powerlaw(), st.sampled_from(["triangle", "q1", "q2"]))
    def test_fused_matches_unfused_and_oracle_powerlaw(graph, qname):
        query = Q.PAPER_QUERIES.get(qname) or getattr(Q, qname)()
        oracle = count_instances(graph, list(query.edges))
        for space in ("huge", "seed", "bigjoin"):
            base, fused = _counts(graph, query, space)
            assert base == fused == oracle, (qname, space, base, fused, oracle)

    @settings(max_examples=2 if FORCE_KERNEL else 8, **SLOW)
    @given(clique_heavy(), st.sampled_from(["triangle", "q2", "q3"]))
    def test_fused_matches_unfused_and_oracle_cliques(graph, qname):
        """Clique-heavy graphs stress the multiway intersection (dense
        adjacency overlap) and the symmetry-breaking orders (many automorphic
        embeddings)."""
        query = Q.PAPER_QUERIES.get(qname) or getattr(Q, qname)()
        oracle = count_instances(graph, list(query.edges))
        for space in ("huge", "seed"):
            base, fused = _counts(graph, query, space)
            assert base == fused == oracle, (qname, space, base, fused, oracle)


# Deterministic fixed-seed differential sweep — the harness's always-on core,
# independent of hypothesis availability.
@pytest.mark.parametrize("seed,qname,space", [
    (3, "triangle", "huge"),
    (7, "q1", "huge"),
    (11, "q2", "seed"),
    (19, "q1", "bigjoin"),
])
def test_fused_matches_unfused_and_oracle_fixed(seed, qname, space):
    graph = powerlaw_graph(40, 5.0, seed=seed)
    query = Q.PAPER_QUERIES.get(qname) or getattr(Q, qname)()
    oracle = count_instances(graph, list(query.edges))
    base, fused = _counts(graph, query, space)
    assert base == fused == oracle, (qname, space, base, fused, oracle)


@pytest.mark.parametrize("nc,cs,qname", [(3, 4, "triangle"), (4, 5, "q2")])
def test_fused_matches_oracle_cliques_fixed(nc, cs, qname):
    graph = ring_of_cliques(nc, cs)
    query = Q.PAPER_QUERIES.get(qname) or getattr(Q, qname)()
    oracle = count_instances(graph, list(query.edges))
    base, fused = _counts(graph, query, "huge")
    assert base == fused == oracle, (qname, base, fused, oracle)


def test_fused_interpret_kernels_exact():
    """Deterministic always-on interpret-mode check (independent of the env
    flag): the full fused kernel path must reproduce the oracle count."""
    graph = powerlaw_graph(1 << 5, 4.0, seed=1)
    query = Q.PAPER_QUERIES["q2"]
    oracle = count_instances(graph, list(query.edges))
    cfg = EngineConfig(**{**_CFG, "join_buffer_capacity": 1 << 10})
    got = HugeEngine(
        graph,
        EngineConfig(**{**_CFG, "join_buffer_capacity": 1 << 10},
                     fused=True, force_kernel=True),
    ).run(query).count
    base = HugeEngine(graph, cfg).run(query).count
    assert got == base == oracle


def test_fused_value_cache_reuse_still_exact():
    """Back-to-back batches re-hit the LRBU value cache; counts must not
    drift as slabs start being served from the cache instead of the graph."""
    graph = ring_of_cliques(4, 5)
    query = Q.triangle()
    oracle = count_instances(graph, list(query.edges))
    eng = HugeEngine(graph, EngineConfig(**_CFG, fused=True))
    assert eng.run(query).count == oracle
