"""Chaos matrix: injected faults across engine + distributed + service.

Every leg arms a deterministic :class:`FaultPlan` (seed taken from
``REPRO_FAULT_SEED`` so the CI chaos job sweeps trigger points without
losing replayability) and asserts the acceptance bar from
DESIGN.md §Fault-tolerance: a run under injection either

* **recovers** — final count identical to the fault-free oracle, with the
  recovery visible in the stats (``pressure_events`` / ``restarts`` /
  ``kernel_fallbacks``); or
* **fails structurally** — an :class:`EnumerationFault` carrying kind / op /
  query attribution, with *zero* leaked pool cells or tenant inflight slots.

The "huge"-space q1–q3 plans contain no PUSH-JOINs, so join-overflow legs
run the same queries in the join-only ``"starjoin"`` space.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.engine import EngineConfig, EngineSession, HugeEngine
from repro.core.faults import (
    FAULT_KINDS,
    EnumerationFault,
    FaultPlan,
    FaultSpec,
    QueuePressure,
)
from repro.core.query import PAPER_QUERIES
from repro.graph import powerlaw_graph
from repro.graph.oracle import count_instances
from repro.serve.graph_service import (
    DONE,
    FAILED,
    TIMED_OUT,
    GraphQueryRequest,
    GraphService,
    ServiceConfig,
)

SEED = int(os.environ.get("REPRO_FAULT_SEED", "0"))
QUERIES = ("q1", "q2", "q3")


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(256, 5.0, seed=3)


@pytest.fixture(scope="module")
def oracle(graph):
    cache = {}

    def _oracle(qname):
        if qname not in cache:
            cache[qname] = count_instances(
                graph, list(PAPER_QUERIES[qname].edges))
        return cache[qname]

    return _oracle


def _plan(kind, op="*", at_step=None):
    return FaultPlan.single(kind, op=op, at_step=at_step, seed=SEED)


def engine_cfg(**kw):
    base = dict(batch_size=128, queue_capacity=1 << 14,
                join_buffer_capacity=1 << 16)
    base.update(kw)
    return EngineConfig(**base)


def svc_cfg(**kw):
    base = dict(queue_capacity=1 << 10, join_buffer_capacity=1 << 12,
                tick_steps=16, max_active=4)
    base.update(kw)
    return ServiceConfig(**base)


# ---------------------------------------------------------------------------
# fault plan determinism
# ---------------------------------------------------------------------------

def test_fault_plan_is_deterministic_and_seed_sensitive():
    a = FaultPlan.single("queue-overflow", seed=SEED)
    b = FaultPlan.single("queue-overflow", seed=SEED)
    fires_a = [a.should_fire("queue-overflow", "SCAN(0, 1)") for _ in range(10)]
    fires_b = [b.should_fire("queue-overflow", "SCAN(0, 1)") for _ in range(10)]
    assert fires_a == fires_b and sum(fires_a) == 1  # one-shot, same step
    a.reset()
    assert [a.should_fire("queue-overflow", "SCAN(0, 1)")
            for _ in range(10)] == fires_a


def test_fault_plan_env_and_validation(monkeypatch):
    assert FaultPlan.from_env({}) is None
    fp = FaultPlan.from_env({"REPRO_FAULT_KIND": "shard-loss",
                             "REPRO_FAULT_SEED": "7",
                             "REPRO_FAULT_OP": "scan",
                             "REPRO_FAULT_STEP": "2"})
    assert fp.seed == 7 and fp.specs[0] == FaultSpec("shard-loss", "scan", 2)
    with pytest.raises(ValueError):
        FaultSpec("not-a-kind")


# ---------------------------------------------------------------------------
# single-process engine: recovery ladder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qname", QUERIES)
def test_engine_recovers_queue_overflow(graph, oracle, qname):
    fp = _plan("queue-overflow", at_step=SEED % 3)
    eng = HugeEngine(graph, engine_cfg(faults=fp, recover=True))
    res = eng.run(PAPER_QUERIES[qname])
    assert fp.fired_count("queue-overflow") == 1
    assert res.count == oracle(qname), qname
    assert res.stats.pressure_events >= 1 and res.stats.retries >= 1


@pytest.mark.parametrize("qname", QUERIES)
def test_engine_recovers_shard_loss(graph, oracle, qname):
    fp = _plan("shard-loss", at_step=SEED % 3)
    eng = HugeEngine(graph, engine_cfg(faults=fp, recover=True))
    res = eng.run(PAPER_QUERIES[qname])
    assert fp.fired_count("shard-loss") == 1
    assert res.count == oracle(qname), qname
    assert res.stats.restarts >= 1


@pytest.mark.parametrize("qname", QUERIES)
def test_engine_kernel_fail_falls_back_to_ref(graph, oracle, qname):
    fp = _plan("kernel-fail", at_step=SEED % 2)
    eng = HugeEngine(graph, engine_cfg(faults=fp, fused=True, recover=True))
    res = eng.run(PAPER_QUERIES[qname])
    assert fp.fired_count("kernel-fail") == 1
    assert res.count == oracle(qname), qname
    assert res.stats.kernel_fallbacks >= 1
    assert res.stats.retries == 0  # one-shot fallback, not a restart


@pytest.mark.parametrize("qname", QUERIES)
def test_engine_recovers_join_overflow_starjoin(graph, oracle, qname):
    fp = _plan("join-overflow", at_step=SEED % 2)
    eng = HugeEngine(graph, engine_cfg(faults=fp, recover=True))
    res = eng.run(PAPER_QUERIES[qname], space="starjoin")
    assert fp.fired_count("join-overflow") == 1
    assert res.count == oracle(qname), qname
    assert res.stats.pressure_events >= 1


def test_engine_fault_is_structured_when_recovery_disabled(graph):
    fp = _plan("queue-overflow", at_step=0)
    eng = HugeEngine(graph, engine_cfg(faults=fp, recover=False))
    with pytest.raises(QueuePressure) as ei:
        eng.run(PAPER_QUERIES["q1"])
    f = ei.value
    assert f.kind == "queue-overflow" and f.recoverable
    assert f.op != "?" and f.query == "square"  # attributable


def test_engine_ladder_exhaustion_escalates(graph):
    # Fault re-fires on every attempt; the batch floor equals the starting
    # batch, so the very first halving attempt must escalate structurally.
    fp = FaultPlan.single("queue-overflow", at_step=0, times=100, seed=SEED)
    eng = HugeEngine(graph, engine_cfg(
        batch_size=64, min_batch_size=64, faults=fp, recover=True))
    with pytest.raises(EnumerationFault) as ei:
        eng.run(PAPER_QUERIES["q1"])
    assert "recovery ladder exhausted" in str(ei.value)
    assert not ei.value.recoverable


def test_organic_queue_overflow_is_recoverable_pressure():
    # No injection: a real capacity breach raises attributable QueuePressure
    # (recoverable), not a bare crash. End-to-end the scheduler's Lemma-5.2
    # slack gating prevents this state; the queue itself stays defensive.
    import jax.numpy as jnp

    from repro.core.engine import DeviceQueue

    q = DeviceQueue(capacity=100, width=2, label="EXT(v2)", query="q1")
    with pytest.raises(QueuePressure) as ei:
        q.append(jnp.zeros((128, 2), jnp.int32), jnp.int32(128))
    f = ei.value
    assert f.kind == "queue-overflow" and f.recoverable
    assert f.op == "EXT(v2)" and f.query == "q1"


# ---------------------------------------------------------------------------
# checkpoint / resume (exactly-once)
# ---------------------------------------------------------------------------

def test_snapshot_restore_resumes_exactly_once(graph, oracle):
    eng = HugeEngine(graph, engine_cfg())
    sess = eng.prepare(PAPER_QUERIES["q2"])
    while not sess.done() and sess.stats.count == 0:
        sess.tick(4)
    snap = sess.snapshot()
    mid_count = snap["stats"].count
    # "crash": abandon the session, restore into a brand-new one
    resumed = EngineSession.restore(eng, sess.flow, snap)
    assert resumed.stats.count == mid_count  # rollback to the checkpoint
    resumed.run()
    assert resumed.result().count == oracle("q2")


def test_periodic_checkpoints_bound_replay(graph, oracle):
    fp = _plan("queue-overflow", op="ext", at_step=10)
    eng = HugeEngine(graph, engine_cfg(
        faults=fp, recover=True, checkpoint_every_steps=2))
    res = eng.run(PAPER_QUERIES["q1"])
    assert res.count == oracle("q1")
    assert res.stats.pressure_events >= 1


# ---------------------------------------------------------------------------
# service: admission faults, retry/backoff, deadlines, lease hygiene
# ---------------------------------------------------------------------------

def test_service_lease_oom_is_transient(graph, oracle):
    fp = _plan("lease-oom", op="admit", at_step=0)
    svc = GraphService(graph, svc_cfg(faults=fp))
    t = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    svc.run_until_idle()
    assert t.status == DONE and t.count == oracle("q1")
    assert any("lease-oom" in f for f in t.failures)
    assert svc.pool.leased_cells == 0


def test_service_crash_releases_lease_and_inflight(graph):
    """Satellite 4: a query crashing mid-run must return the pool to its
    pre-admission state and free the tenant's inflight slot."""
    ecfg = engine_cfg(
        faults=_plan("queue-overflow", op="scan", at_step=1), recover=True)
    svc = GraphService(graph, svc_cfg(max_retries=0), engine_cfg=ecfg)
    pre_cells = svc.pool.leased_cells
    pre_inflight = svc.tenant_usage("a")["inflight"]
    t = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    svc.run_until_idle()
    assert t.status == FAILED
    assert "queue-overflow" in t.error and t.failures
    assert svc.pool.leased_cells == pre_cells
    assert svc.tenant_usage("a") == {"inflight": pre_inflight,
                                     "queue_cells": 0}
    assert not svc.active and not svc.admission


def test_service_retries_with_backoff_and_succeeds(graph, oracle):
    ecfg = engine_cfg(
        faults=_plan("queue-overflow", op="scan", at_step=1), recover=True)
    svc = GraphService(graph, svc_cfg(max_retries=2, retry_backoff_ticks=1),
                       engine_cfg=ecfg)
    t = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    svc.run_until_idle()
    assert t.status == DONE and t.count == oracle("q1")
    assert t.attempts == 2 and len(t.failures) == 1
    assert svc.pool.leased_cells == 0


def test_service_checkpoint_degrades_in_place(graph, oracle):
    ecfg = engine_cfg(faults=_plan("queue-overflow", op="ext", at_step=6),
                      recover=True)
    svc = GraphService(graph, svc_cfg(checkpoint_every_ticks=1, tick_steps=4),
                       engine_cfg=ecfg)
    t = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    svc.run_until_idle()
    assert t.status == DONE and t.count == oracle("q1")
    assert t.attempts == 1              # degraded in place, never requeued
    assert t.stats.pressure_events >= 1
    assert svc.pool.leased_cells == 0


def test_service_deadline_times_out(graph):
    svc = GraphService(graph, svc_cfg())
    t = svc.submit(GraphQueryRequest(tenant="a", query="q1", deadline_s=0.0))
    svc.run_until_idle()
    assert t.status == TIMED_OUT and t.error
    assert svc.pool.leased_cells == 0
    assert svc.tenant_usage("a")["inflight"] == 0


def test_service_snapshot_restore_resumes_running_and_standing(graph, oracle):
    svc = GraphService(graph, svc_cfg(checkpoint_every_ticks=1, tick_steps=4))
    sq = svc.register_standing("s", "q2")
    sq.total_count = 41  # accumulated by (pretend) earlier batches
    svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    for _ in range(6):
        svc.tick()
    assert svc.active, "query must still be mid-flight for the crash test"
    snap = svc.snapshot()
    assert snap["running"] and snap["standing"]
    # simulated crash: rebuild the whole service from the snapshot
    svc2 = GraphService.restore(graph, snap,
                                svc_cfg(checkpoint_every_ticks=1))
    assert svc2.standing[0].total_count == 41
    svc2.run_until_idle()
    assert svc2.pool.leased_cells == 0
    # exactly-once: resume via the public API with a tracked ticket
    svc3 = GraphService(graph, svc_cfg())
    req, flow, sess_snap = snap["running"][0]
    t = svc3.resume(req, flow, sess_snap)
    svc3.run_until_idle()
    assert t.status == DONE and t.count == oracle("q1")


def test_queue_slot_pool_over_release_is_an_error(graph):
    from repro.core.engine import QueueSlotPool

    pool = QueueSlotPool(1000)
    assert pool.try_lease(100)
    with pytest.raises(RuntimeError, match="over-release"):
        pool.release(200)
    assert pool.leased_cells == 0  # clamped, not negative


# ---------------------------------------------------------------------------
# distributed engine (fresh interpreter: XLA device count must precede jax)
# ---------------------------------------------------------------------------

def _run_py(code, timeout=540, devices=4):
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    r = subprocess.run([sys.executable, "-u", "-c", textwrap.dedent(code)],
                       env=env, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_distributed_chaos_matrix():
    """All four engine-level fault kinds on the 4-shard SPMD engine: each
    must recover (restart or degraded batch) to the oracle count."""
    out = _run_py(f"""
        import jax
        from repro.core import query as Q
        from repro.core.distributed import DistributedEngine, DistConfig
        from repro.core.faults import FaultPlan
        from repro.graph import powerlaw_graph
        from repro.graph.oracle import count_instances

        SEED = {SEED}
        mesh = jax.make_mesh((4,), ("shards",))
        g = powerlaw_graph(220, 5.0, seed=4)
        q = Q.PAPER_QUERIES["q1"]
        oracle = count_instances(g, list(q.edges))

        def run(kind, op="*", at_step=None, space="huge", fused=False):
            fp = FaultPlan.single(kind, op=op, at_step=at_step, seed=SEED)
            cfg = DistConfig(batch_size=128, queue_capacity=1 << 14,
                             faults=fp, recover=True, fused=fused)
            eng = DistributedEngine(g, mesh, cfg)
            count, stats = eng.run(q, space=space)
            return fp, count, stats

        fp, count, stats = run("queue-overflow", at_step=SEED % 3)
        assert fp.fired_count() == 1 and count == oracle, (count, oracle)
        assert stats["retries"] >= 1 and stats["pressure_events"] >= 1
        print("queue-overflow ok", count)

        fp, count, stats = run("shard-loss", at_step=SEED % 3)
        assert fp.fired_count() == 1 and count == oracle, (count, oracle)
        assert stats["restarts"] >= 1
        print("shard-loss ok", count)

        fp, count, stats = run("kernel-fail", at_step=SEED % 2, fused=True)
        assert fp.fired_count() == 1 and count == oracle, (count, oracle)
        assert stats["kernel_fallbacks"] >= 1
        print("kernel-fail ok", count)

        oracle3 = count_instances(g, list(Q.PAPER_QUERIES["q3"].edges))
        fp = FaultPlan.single("join-overflow", at_step=SEED % 2, seed=SEED)
        cfg = DistConfig(batch_size=128, queue_capacity=1 << 14,
                         join_out_capacity=1 << 18, faults=fp, recover=True)
        eng = DistributedEngine(g, mesh, cfg)
        count, stats = eng.run(Q.PAPER_QUERIES["q3"], space="starjoin")
        assert fp.fired_count() == 1 and count == oracle3, (count, oracle3)
        assert stats["pressure_events"] >= 1
        print("join-overflow ok", count)
    """)
    assert out.count("ok") == 4
