"""Dry-run machinery self-test: lower+compile real full-size cells on a
scaled 8-device mesh in a subprocess (the 512-device sweep is the deliverable;
this keeps the machinery covered by CI)."""
import json
import os
import subprocess
import sys

ENV = dict(os.environ, PYTHONPATH="src", DRYRUN_DEVICES="8")


def _run_cell(arch, shape, mesh, tmpdir):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(tmpdir)],
        env=ENV, cwd="/root/repo", capture_output=True, text=True, timeout=540,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    with open(os.path.join(str(tmpdir), f"{arch}__{shape}__{mesh}.json")) as f:
        return json.load(f)


def test_decode_cell_compiles_and_reports(tmp_path):
    rec = _run_cell("granite-3-8b", "decode_32k", "single", tmp_path)
    assert rec["ok"], rec.get("error")
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["counted"]["flops"] > 0
    assert rec["memory"]["temp_size_in_bytes"] > 0


def test_long_context_ssm_cell_multi_pod(tmp_path):
    rec = _run_cell("rwkv6-7b", "long_500k", "multi", tmp_path)
    assert rec["ok"], rec.get("error")
    # O(1)-state decode: tiny memory term relative to a KV-cache arch
    assert rec["roofline"]["memory_s"] < 1.0


def test_full_attention_long_context_is_skipped(tmp_path):
    rec = _run_cell("granite-3-8b", "long_500k", "single", tmp_path)
    assert "SKIP" in rec.get("skip", "")
