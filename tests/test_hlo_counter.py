"""hlo_counter exactness: single-device cases (multi-device in test_distributed)."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_counter import analyze


def _flops_of(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return analyze(jax.jit(fn).lower(*args).compile().as_text())


def test_scan_trip_multiplication():
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=10)
        return y

    c = _flops_of(f, (512, 512))
    expect = 10 * 2 * 512**3
    assert abs(c.flops - expect) / expect < 0.01


def test_nested_scan():
    def f(x):
        def inner(y, _):
            return y @ y, None

        def outer(y, _):
            y, _ = jax.lax.scan(inner, y, None, length=5)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = _flops_of(f, (256, 256))
    expect = 15 * 2 * 256**3
    assert abs(c.flops - expect) / expect < 0.01


def test_batched_dot_flops():
    def f(x, y):
        return jnp.einsum("bij,bjk->bik", x, y)

    c = _flops_of(f, (4, 128, 256), (4, 256, 64))
    expect = 2 * 4 * 128 * 256 * 64
    assert abs(c.flops - expect) / expect < 0.01


def test_dus_stack_write_counted_at_update_size():
    """A scan writing into a stacked output must charge the slice, not the
    whole stack, per iteration."""
    def f(x):
        def body(c, _):
            return c + 1.0, c  # ys stacked [32, 256, 256]

        _, ys = jax.lax.scan(body, x, None, length=32)
        return ys

    c = _flops_of(f, (256, 256))
    stack = 32 * 256 * 256 * 4
    # true traffic ≈ 32 × (read c + write slice) ≈ 2-4× the stack bytes;
    # a result-sized DUS accounting would charge ≈ 32 × stack = 32×.
    assert c.bytes < 8 * stack, f"{c.bytes} vs stack {stack}"
