"""Serving engine: batched prefill+decode, greedy matches argmax of forward."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import transformer as T
from repro.serve.engine import BatchedServer, Request, ServeConfig


def test_greedy_serving_matches_forward_argmax():
    cfg = smoke_config("granite-3-8b")
    params = T.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, size=12).astype(np.int32)

    scfg = ServeConfig(max_len=32, batch_slots=2, temperature=0.0,
                       max_new_tokens=5, eos_token=-1)
    server = BatchedServer(cfg, params, scfg)
    reqs = [Request(prompt=prompt.copy()), Request(prompt=prompt.copy())]
    stats = server.run(reqs)
    assert stats["new_tokens"] > 0
    # identical prompts in the same batch → identical outputs
    assert reqs[0].out_tokens == reqs[1].out_tokens
    # first generated token == argmax of the forward pass at the last position
    logits = T.forward(cfg, params, {"tokens": jnp.asarray(prompt[None])})
    expect = int(jnp.argmax(logits[0, -1]))
    assert reqs[0].out_tokens[0] == expect


def test_serving_throughput_counts():
    cfg = smoke_config("rwkv6-7b")
    params = T.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(1)
    scfg = ServeConfig(max_len=24, batch_slots=4, temperature=0.7,
                       max_new_tokens=4, eos_token=-1)
    server = BatchedServer(cfg, params, scfg)
    reqs = [Request(prompt=rng.integers(2, cfg.vocab_size, size=8).astype(np.int32))
            for _ in range(6)]
    stats = server.run(reqs)
    assert stats["requests"] == 6
    assert all(r.done for r in reqs)
    assert stats["tokens_per_s"] > 0
