"""Tracer-safety lint tests (repro/analysis/tracelint.py).

Covers: the seeded bad-source fixture fires every rule; trace-time-static
constructs (shape branching, static_argnames params, closure flags) are NOT
flagged; queue-dtype drift detection; baseline suppression round-trip; and
the repo acceptance check — src/repro lints clean against the checked-in
baseline file.
"""
import os
import textwrap

import repro
from repro.analysis.diagnostics import load_baseline, split_baselined
from repro.analysis.fixtures import BAD_TRACED_SOURCE
from repro.analysis.tracelint import check_kernel_twins, lint_source, lint_tree

SRC_ROOT = os.path.dirname(os.path.abspath(repro.__file__))
REPO_ROOT = os.path.dirname(os.path.dirname(SRC_ROOT))
BASELINE = os.path.join(REPO_ROOT, "analysis", "baseline.txt")
KERNEL_TESTS = os.path.join(REPO_ROOT, "tests", "test_kernels.py")


def _rules(diags):
    return {d.rule for d in diags}


# ---------------------------------------------------------------------------
# seeded bad source fires every rule
# ---------------------------------------------------------------------------

def test_bad_source_fires_all_rules():
    diags = lint_source(BAD_TRACED_SOURCE, "fixture.py")
    assert {"traced-branch", "host-sync", "queue-dtype"} <= _rules(diags)


def test_traced_branch_symbols():
    diags = lint_source(BAD_TRACED_SOURCE, "fixture.py")
    symbols = {d.where.rsplit("::", 1)[-1] for d in diags if d.rule == "traced-branch"}
    assert {"if", "assert"} <= symbols


def test_host_sync_variants():
    src = textwrap.dedent("""\
        import jax, numpy as np

        @jax.jit
        def f(x):
            a = int(x.sum())
            b = x.item()
            c = np.asarray(x)
            return a, b, c
    """)
    diags = [d for d in lint_source(src, "m.py") if d.rule == "host-sync"]
    symbols = {d.where.rsplit("::", 1)[-1] for d in diags}
    assert {"int", "item", "np.asarray"} <= symbols


# ---------------------------------------------------------------------------
# trace-time-static constructs are NOT flagged
# ---------------------------------------------------------------------------

def test_shape_branching_not_flagged():
    src = textwrap.dedent("""\
        import jax, jax.numpy as jnp

        @jax.jit
        def f(x):
            b, d = x.shape
            assert b % 8 == 0
            if d > 16:
                x = x[:, :16]
            if len(x.shape) == 2:
                x = x[None]
            n = x.ndim
            while n > 3:
                n -= 1
            return x
    """)
    assert lint_source(src, "m.py") == []


def test_static_argnames_not_tainted():
    src = textwrap.dedent("""\
        import functools, jax, jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("use_kernel", "cap"))
        def f(x, use_kernel, cap):
            if use_kernel:
                x = x * 2
            assert cap > 0
            if x.sum() > 0:   # still flagged: x IS a tracer
                x = -x
            return x
    """)
    diags = lint_source(src, "m.py")
    assert len([d for d in diags if d.rule == "traced-branch"]) == 1


def test_closure_flags_not_tainted():
    src = textwrap.dedent("""\
        import jax

        def build(causal):
            @jax.jit
            def f(x):
                if causal:
                    x = x + 1
                return x
            return f
    """)
    assert lint_source(src, "m.py") == []


def test_untraced_function_not_linted():
    src = textwrap.dedent("""\
        def plain(x):
            if x > 0:
                return int(x)
            return 0
    """)
    assert lint_source(src, "m.py") == []


# ---------------------------------------------------------------------------
# queue dtype drift
# ---------------------------------------------------------------------------

def test_queue_dtype_missing_and_wrong():
    src = textwrap.dedent("""\
        import jax.numpy as jnp
        from repro.graph.storage import INVALID

        def make(cap, k):
            queue_buf = jnp.full((cap, k), INVALID)
            bad_buf = jnp.full((cap, k), INVALID, jnp.int64)
            good_buf = jnp.full((cap, k), INVALID, jnp.int32)
            other = jnp.full((cap, k), 0.0)
            return queue_buf, bad_buf, good_buf, other
    """)
    diags = [d for d in lint_source(src, "m.py") if d.rule == "queue-dtype"]
    names = {d.where.rsplit("::", 1)[-1] for d in diags}
    assert names == {"queue_buf", "bad_buf"}


# ---------------------------------------------------------------------------
# kernel twin contract
# ---------------------------------------------------------------------------

def test_kernel_twins_on_real_tree():
    diags = check_kernel_twins(os.path.join(SRC_ROOT, "kernels"), KERNEL_TESTS)
    # the only allowed gap is the baselined flash_attention ref naming
    keys = {d.key() for d in diags}
    baseline = load_baseline(BASELINE)
    assert keys <= set(baseline), f"unbaselined kernel findings: {keys - set(baseline)}"


# ---------------------------------------------------------------------------
# baseline round-trip + repo acceptance
# ---------------------------------------------------------------------------

def test_baseline_suppression(tmp_path):
    diags = lint_source(BAD_TRACED_SOURCE, "fixture.py")
    bl = tmp_path / "bl.txt"
    bl.write_text("".join(f"{d.key()}  # justified\n" for d in diags))
    new, suppressed = split_baselined(diags, load_baseline(str(bl)))
    assert new == [] and len(suppressed) == len(diags)


def test_repo_lints_clean_against_baseline():
    findings = lint_tree(SRC_ROOT, KERNEL_TESTS)
    baseline = load_baseline(BASELINE)
    new = [d for d in findings if d.key() not in baseline and d.severity == "error"]
    assert new == [], "unbaselined lint findings:\n" + "\n".join(
        d.format() for d in new)
