"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape + finiteness assertions; decode-path equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, all_cells, get_config, smoke_config
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_all, make_train_step

RNG = np.random.default_rng(0)


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.encoder_layers:
        batch["frontend"] = jnp.asarray(
            RNG.standard_normal((b, 8, cfg.d_model)), jnp.bfloat16
        )
    elif cfg.frontend:
        batch["frontend"] = jnp.asarray(
            RNG.standard_normal((b, cfg.frontend_len, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    logits = T.forward(cfg, params, batch)
    b, s = batch["tokens"].shape
    front = cfg.frontend_len if (cfg.frontend and cfg.family != "audio") else 0
    assert logits.shape == (b, s + front, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # one train step: loss finite, grads applied
    tc = TrainConfig(adamw=AdamWConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10))
    params, opt = init_all(cfg, tc, jax.random.key(0))
    step = make_train_step(cfg, tc)
    p2, o2, m = step(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["granite-3-8b", "gemma2-9b", "chatglm3-6b",
                                  "jamba-v0.1-52b", "rwkv6-7b",
                                  "qwen3-moe-30b-a3b", "seamless-m4t-large-v2",
                                  "phi-3-vision-4.2b"])
def test_prefill_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.key(1))
    b, s, extra = 2, 16, 3
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s + extra)))
    full_b = {"tokens": toks}
    pre_b = {"tokens": toks[:, :s]}
    front = 0
    if cfg.encoder_layers:
        fe = jnp.asarray(RNG.standard_normal((b, 8, cfg.d_model)), jnp.bfloat16)
        full_b["frontend"] = pre_b["frontend"] = fe
    elif cfg.frontend:
        fe = jnp.asarray(RNG.standard_normal((b, cfg.frontend_len, cfg.d_model)), jnp.bfloat16)
        full_b["frontend"] = pre_b["frontend"] = fe
        front = cfg.frontend_len
    full = T.forward(cfg, params, full_b).astype(jnp.float32)
    cache, last = T.prefill(cfg, params, pre_b, max_len=front + s + extra + 2)
    err = float(jnp.max(jnp.abs(last[:, 0].astype(jnp.float32) - full[:, front + s - 1])))
    assert err < 0.05, f"prefill mismatch {err}"
    pos = front + s
    for i in range(extra):
        logits, cache = T.decode_step(cfg, params, cache, toks[:, s + i : s + i + 1], jnp.int32(pos))
        err = float(jnp.max(jnp.abs(logits[:, 0].astype(jnp.float32) - full[:, front + s + i])))
        assert err < 0.05, f"decode step {i} mismatch {err}"
        pos += 1


def test_exact_configs_match_assignment():
    """The full configs carry exactly the assigned dimensions."""
    spec = {
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }
    for arch, (nl, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (nl, d, h, kv, ff, v), (arch, got)
    assert get_config("qwen3-moe-30b-a3b").num_experts == 128
    assert get_config("qwen3-moe-30b-a3b").experts_per_token == 8
    assert get_config("arctic-480b").num_experts == 128
    assert get_config("arctic-480b").experts_per_token == 2
    assert get_config("arctic-480b").mlp_pattern == ("moe_dense",)
    assert get_config("jamba-v0.1-52b").layer_pattern.count("attn") == 1  # 1:7
    assert get_config("jamba-v0.1-52b").num_experts == 16


def test_cell_grid_is_40_with_documented_skips():
    cells = list(all_cells())
    assert len(cells) == 40
    skipped = [(a, s) for a, s, skip in cells if skip]
    assert len(skipped) == 8  # long_500k for the 8 full-attention archs
    assert all(s == "long_500k" for _, s in skipped)
    runnable_long = [a for a, s, skip in cells if s == "long_500k" and not skip]
    assert sorted(runnable_long) == ["jamba-v0.1-52b", "rwkv6-7b"]


def test_param_count_analytic_close_to_actual():
    for arch in ("granite-3-8b", "rwkv6-7b", "qwen3-moe-30b-a3b"):
        cfg = smoke_config(arch)
        params = T.init_params(cfg, jax.random.key(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.35, (arch, actual, analytic)


def test_moe_auto_decision_crossover():
    """hybrid_comm, Remark 3.1: pulling the fixed-size expert weights wins
    when the routed-token volume exceeds them (qwen3's big train batches
    through 768-wide experts); pushing wins for tiny decode batches."""
    from repro.core.hybrid_comm import moe_dispatch_mode

    train = moe_dispatch_mode(
        tokens_per_step=1 << 18, d_model=2048, d_ff=768, num_experts=128,
        experts_per_token=8, dp_degree=16,
    )
    decode = moe_dispatch_mode(
        tokens_per_step=128, d_model=2048, d_ff=768, num_experts=128,
        experts_per_token=8, dp_degree=16,
    )
    assert train.mode == "pull" and decode.mode == "push"
    assert train.pull_bytes < train.push_bytes
    assert decode.push_bytes < decode.pull_bytes
