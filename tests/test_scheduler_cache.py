"""Scheduler (Alg. 5) and LRBU cache unit tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as lrbu
from repro.core.scheduler import AdaptiveScheduler
from repro.graph.storage import INVALID


class FakeOp:
    """Source→sink toy chain for scheduler semantics."""

    def __init__(self, label, produce, out_cap, slack=1):
        self.label = label
        self.inbox = produce          # items remaining at the source
        self.out = 0                  # items in output queue
        self.consumer = None
        self.out_cap = out_cap
        self.slack = slack
        self.runs = 0

    def has_input(self):
        return self.inbox > 0

    def output_free(self):
        return self.out_cap - self.out

    def required_slack(self):
        return self.slack

    def run_one(self):
        self.inbox -= 1
        self.out += 1
        self.runs += 1
        if self.consumer is not None:
            self.consumer.inbox += 1
            self.out -= 1  # handoff modelled as immediate queue transfer


def chain(*ops):
    for a, b in zip(ops, ops[1:]):
        a.consumer = b
    return list(ops)


def test_scheduler_drains_everything():
    a = FakeOp("scan", 10, 3)
    b = FakeOp("ext", 0, 3)
    c = FakeOp("sink", 0, 1 << 30)
    st = AdaptiveScheduler(chain(a, b, c)).run()
    assert a.inbox == 0 and b.inbox == 0 and c.inbox == 0
    assert c.out == 10 or c.runs == 10
    assert st.steps == 30


def test_scheduler_stall_detection():
    class Blocked(FakeOp):
        def output_free(self):
            return 0

    a = Blocked("stuck", 5, 0)
    b = FakeOp("sink", 0, 10)
    with pytest.raises(RuntimeError, match="stalled"):
        AdaptiveScheduler(chain(a, b)).run()


def test_lrbu_seal_prevents_eviction_within_batch():
    """All ways of a set touched in the same batch → inserts overflow
    deterministically instead of evicting sealed entries."""
    state = lrbu.make_cache(8, ways=2)  # 4 sets × 2 ways
    batch = jnp.asarray([0, 4, 8, INVALID], jnp.int32)  # all map to set 0
    state, hit = lrbu.fetch_update(state, batch)
    assert not bool(hit[0]) and not bool(hit[1])
    # 0 and 4 inserted; 8 overflowed into way 0 (paper's bounded overflow)
    keys0 = np.asarray(state.keys[0])
    assert set(keys0.tolist()) <= {0, 4, 8}
    # next batch: whatever survived must hit
    state2, hit2 = lrbu.fetch_update(state, batch)
    assert int(jnp.sum(hit2[:3])) >= 2


def test_lrbu_evicts_least_recent_batch():
    state = lrbu.make_cache(8, ways=2)
    pad = lambda xs: jnp.asarray(xs + [INVALID] * (4 - len(xs)), jnp.int32)
    state, _ = lrbu.fetch_update(state, pad([0]))      # batch 0: insert 0 (set 0)
    state, _ = lrbu.fetch_update(state, pad([4]))      # batch 1: insert 4 (set 0)
    state, _ = lrbu.fetch_update(state, pad([8]))      # batch 2: evict LRB = 0
    _, hit = lrbu.fetch_update(state, pad([4, 8, 0]))
    assert bool(hit[0]) and bool(hit[1]) and not bool(hit[2])


def test_lrbu_seal_protects_current_batch_hits_from_eviction():
    """Seal contract: an entry hit in the current batch has its epoch bumped
    to current_epoch, so a same-batch insert into the full set must evict the
    *other* (unsealed) way — never the sealed one."""
    state = lrbu.make_cache(8, ways=2)  # 4 sets × 2 ways
    pad = lambda xs: jnp.asarray(xs + [INVALID] * (4 - len(xs)), jnp.int32)
    state, _ = lrbu.fetch_update(state, pad([0, 4]))   # set 0 now full: {0, 4}
    state, hit = lrbu.fetch_update(state, pad([0, 8])) # hit-seal 0, insert 8
    assert bool(hit[0]) and not bool(hit[1])
    keys0 = set(np.asarray(state.keys[0]).tolist())
    assert 0 in keys0, "sealed entry was evicted within its own batch"
    assert 8 in keys0 and 4 not in keys0, "victim must be the unsealed way"


def test_lrbu_release_advances_epochs_monotonically():
    """Release contract: every fetch_update call ends with Release() —
    current_epoch strictly increases, and entries sealed in batch t carry
    exactly epoch t (the ordered-set bookkeeping of Alg. 3)."""
    state = lrbu.make_cache(8, ways=2)
    pad = lambda xs: jnp.asarray(xs + [INVALID] * (4 - len(xs)), jnp.int32)
    seen = [int(state.current_epoch)]
    for t in range(5):
        epoch_at_insert = int(state.current_epoch)
        state, _ = lrbu.fetch_update(state, pad([t]))
        seen.append(int(state.current_epoch))
        sets, way, hit = lrbu._locate(state, pad([t]))
        assert bool(hit[0])
        assert int(state.epoch[int(sets[0]), int(way[0])]) == epoch_at_insert
    assert all(b == a + 1 for a, b in zip(seen, seen[1:])), seen


def test_value_cache_probe_byte_identical_to_storage_fetch():
    """The fused kernel's probe (probe_indices + values table) must serve
    slabs byte-identical to a direct PaddedAdjacency fetch from storage."""
    from repro.graph.generators import powerlaw_graph

    g = powerlaw_graph(64, 4.0, seed=5)
    d_pad = g.padded.d_pad
    state = lrbu.make_cache(64, ways=4, d_pad=d_pad)
    vids = jnp.asarray([1, 5, 9, 13, 21, 40, INVALID, INVALID], jnp.int32)
    direct_rows, direct_degs = g.padded.neighbors(vids)
    state, _ = lrbu.fetch_update_values(state, vids, direct_rows, direct_degs)

    idx, hit = lrbu.probe_indices(state, vids)
    flat_values = np.asarray(state.values.reshape(-1, d_pad))
    valid = np.asarray(vids) != INVALID
    assert bool(jnp.all(hit[:6])), "fresh inserts must probe as hits"
    for i in np.flatnonzero(valid & np.asarray(hit)):
        np.testing.assert_array_equal(
            flat_values[int(idx[i])], np.asarray(direct_rows[i]),
            err_msg=f"slab for vid {int(vids[i])} differs from storage fetch",
        )
    # and the higher-level lookup agrees too
    got_rows, got_degs, got_hit = lrbu.cache_lookup_values(state, vids)
    np.testing.assert_array_equal(
        np.asarray(got_rows[:6]), np.asarray(direct_rows[:6])
    )
    np.testing.assert_array_equal(
        np.asarray(got_degs[:6]), np.asarray(direct_degs[:6])
    )


def test_value_cache_roundtrip():
    state = lrbu.make_cache(16, ways=2, d_pad=8)
    vids = jnp.asarray([3, 7, INVALID, INVALID], jnp.int32)
    rows = jnp.arange(32, dtype=jnp.int32).reshape(4, 8)
    degs = jnp.asarray([8, 8, 0, 0], jnp.int32)
    state, hit = lrbu.fetch_update_values(state, vids, rows, degs)
    got_rows, got_degs, got_hit = lrbu.cache_lookup_values(state, vids)
    assert bool(got_hit[0]) and bool(got_hit[1])
    np.testing.assert_array_equal(np.asarray(got_rows[0]), np.asarray(rows[0]))
    assert int(got_degs[1]) == 8


class FakeJoin:
    """Barrier op over two FakeOp branches: buffers the left side, streams the
    right side only once the left branch has drained (PUSH-JOIN semantics)."""

    def __init__(self, label, left, right):
        self.label = label
        self.left, self.right = left, right
        self.out = 0
        self.probed = 0
        self.runs = 0

    def left_done(self):
        return self.left.inbox == 0

    def has_input(self):
        return self.right.out > 0 and self.left_done()

    def output_free(self):
        return 1 << 30

    def required_slack(self):
        return 0

    def run_one(self):
        self.right.out -= 1
        self.probed += 1
        self.out += 1
        self.runs += 1


def test_scheduler_runs_dag_with_join_barrier():
    """Topologically ordered DAG: two source chains feeding a barrier join.
    The join must not probe before the left branch drains, and everything
    must still terminate with all rows processed."""
    left = FakeOp("scan-L", 7, 1 << 30)
    right = FakeOp("scan-R", 5, 1 << 30)
    right.consumer = None  # right rows accumulate in right.out
    join = FakeJoin("join", left, right)
    order = [left, right, join]

    probed_before_left_done = []
    orig = join.run_one

    def run_one():
        probed_before_left_done.append(left.inbox > 0)
        orig()

    join.run_one = run_one
    AdaptiveScheduler(order).run()
    assert join.probed == 5
    assert not any(probed_before_left_done), "join probed before the barrier"


def test_scheduler_advances_past_drained_siblings():
    """A blocked op must not trap the cursor when its drain lies downstream
    past a drained sibling branch (the DAG ping-pong regression)."""
    class Sticky(FakeOp):
        """Produces into a bounded queue drained only by the far consumer."""
        def __init__(self, label, produce):
            super().__init__(label, produce, out_cap=2, slack=1)

    src = Sticky("src", 6)
    drained = FakeOp("sibling", 0, 1 << 30)
    far = FakeOp("far-consumer", 0, 1 << 30)

    # wire: src.out consumed by far (two positions later in topo order)
    def far_has_input():
        return src.out > 0

    def far_run_one():
        src.out -= 1
        far.runs += 1

    far.has_input = far_has_input
    far.run_one = far_run_one
    AdaptiveScheduler([src, drained, far]).run()
    assert src.inbox == 0 and src.out == 0
    assert far.runs == 6
