"""Paper Exp-9: hybrid plan comparison — wco-only vs sequential-context hybrid
planners (EmptyHeaded/GraphFlow ≈ computation-only cost) vs HUGE (computation
+ communication cost)."""
from __future__ import annotations

from benchmarks.common import bench_graph, emit, run_query


def main():
    graph = bench_graph(n=1 << 10, deg=5.0)  # GO-like (paper uses GO here)
    for qname in ("q7", "q8"):
        for label, space in (
            ("HUGE-WCO", "bigjoin"),
            ("HUGE-EH", "emptyheaded"),
            ("HUGE", "huge"),
        ):
            try:
                res = run_query(graph, qname, space=space, queue_capacity=1 << 18,
                                batch_size=128, join_out_capacity=1 << 21)
            except ValueError as e:  # plan infeasible in this space
                emit(f"exp9/{label}/{qname}", 0.0, f"infeasible:{e}")
                continue
            s = res.stats
            emit(
                f"exp9/{label}/{qname}",
                s.wall_time * 1e6,
                f"T={s.wall_time:.2f}s;C={s.total_comm_bytes / 1e6:.2f}MB;count={res.count}",
            )


if __name__ == "__main__":
    main()
