"""§Roofline: aggregate the dry-run JSONs into the per-cell roofline table.

Terms (per device, per step; TPU v5e constants in launch/mesh.py):
  compute    = counted HLO dot-FLOPs / 197e12
  memory     = counted HBM traffic   / 819e9
  collective = counted wire bytes    / 50e9 (ICI) | 25e9 (DCI multi-pod)

"counted" = hlo_counter static analysis with while-loop trip multiplication
(XLA's cost_analysis counts loop bodies once — see hlo_counter.py).

Usage:
  python -m benchmarks.roofline                    # table from reports/dryrun
  python -m benchmarks.roofline --dir A --compare B   # perf-iteration diff
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")


def load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        with open(f) as fh:
            r = json.load(fh)
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:7.2f}s"
    return f"{x * 1e3:6.1f}ms"


def row(r):
    if r.get("skip"):
        return f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s}  {r['skip']}"
    if not r.get("ok"):
        return f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s}  FAIL: {str(r.get('error'))[:70]}"
    rf = r["roofline"]
    bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
    frac = rf["compute_s"] / max(bound, 1e-12)
    return (
        f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s}  "
        f"C={fmt_s(rf['compute_s'])} M={fmt_s(rf['memory_s'])} "
        f"X={fmt_s(rf['collective_s'])}  dom={rf['dominant']:10s} "
        f"roofline_frac={frac:5.1%} useful={r.get('useful_ratio', 0):5.1%}"
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DEFAULT_DIR)
    ap.add_argument("--compare", default=None, help="second reports dir to diff")
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    args = ap.parse_args(argv)

    recs = load(args.dir)
    keys = sorted(recs)
    print(f"== roofline table ({len(keys)} cells) ==")
    for k in keys:
        if args.mesh and k[2] != args.mesh:
            continue
        print(row(recs[k]))

    if args.compare:
        other = load(args.compare)
        print(f"\n== diff vs {args.compare} ==")
        for k in sorted(set(recs) & set(other)):
            a, b = recs[k], other[k]
            if not (a.get("ok") and b.get("ok")):
                continue
            ra, rb = a["roofline"], b["roofline"]
            ba = max(ra["compute_s"], ra["memory_s"], ra["collective_s"])
            bb = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
            print(
                f"{k[0]:24s} {k[1]:12s} {k[2]:6s} bound {fmt_s(ba)} -> {fmt_s(bb)} "
                f"({ba / max(bb, 1e-12):.2f}x) dom {ra['dominant']}->{rb['dominant']}"
            )


if __name__ == "__main__":
    main()
