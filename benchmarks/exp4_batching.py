"""Paper Exp-4: effect of batch size (cache disabled, as in the paper).

Larger batches aggregate more pull requests per round (merged RPCs): measured
as pulled bytes (dedup within batch) and wall time.
"""
from __future__ import annotations

from benchmarks.common import bench_graph, emit, run_query


def main():
    graph = bench_graph()
    for qname in ("q1", "q3"):
        base = None
        for batch in (128, 256, 512, 1024):
            res = run_query(graph, qname, batch_size=batch, cache_capacity=0)
            s = res.stats
            base = base or s.pulled_bytes
            emit(
                f"exp4/batch={batch}/{qname}",
                s.wall_time * 1e6,
                f"pulled={s.pulled_bytes / 1e6:.2f}MB;"
                f"dedup_gain={base / max(s.pulled_bytes, 1):.2f}x;count={res.count}",
            )


if __name__ == "__main__":
    main()
