"""Service-load benchmark: multi-tenant graph service under mixed q1–q3 traffic.

The ROADMAP serving item made concrete: T tenants each submit R enumeration
requests (round-robin over q1=square, q2=diamond, q3=4-clique) to ONE
``GraphService`` sharing one engine; the driver ticks the service to idle and
reports per-request latency percentiles (p50/p99, stamped per request from
submit to finish) plus aggregate matches/sec. Results append to
``BENCH_service.json`` via ``common.record_bench`` (EXPERIMENTS.md
§Service-load).

  PYTHONPATH=src python -m benchmarks.exp_service_load             # default load
  PYTHONPATH=src python -m benchmarks.exp_service_load --smoke     # CI: 2 tenants, tiny graph

A warmup pass (same workload, discarded) runs first so the percentiles
measure steady-state serving, not jit compilation; ``--no-warmup`` skips it
(compile time then lands in the first requests' latencies).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import bench_graph, emit, record_bench
from repro.core.engine import EngineConfig
from repro.serve.graph_service import (
    DONE,
    GraphQueryRequest,
    GraphService,
    ServiceConfig,
)

MIX = ("q1", "q2", "q3")


def build_service(graph, max_active: int, tick_steps: int) -> GraphService:
    return GraphService(
        graph,
        ServiceConfig(
            max_active=max_active,
            tick_steps=tick_steps,
            queue_capacity=1 << 12,
            join_buffer_capacity=1 << 14,
        ),
        EngineConfig(batch_size=256, cache_capacity=1 << 12),
    )


def run_load(graph, tenants: int, requests: int, max_active: int,
             tick_steps: int) -> dict:
    """Submit ``tenants × requests`` mixed queries, tick to idle, measure."""
    svc = build_service(graph, max_active, tick_steps)
    t0 = time.perf_counter()
    tickets = []
    # Interleave tenants in submission order — the admission queue sees mixed
    # traffic, not one tenant's burst followed by another's.
    for r in range(requests):
        for t in range(tenants):
            q = MIX[(r * tenants + t) % len(MIX)]
            tickets.append(
                svc.submit(GraphQueryRequest(tenant=f"tenant{t}", query=q))
            )
    svc.run_until_idle()
    wall = time.perf_counter() - t0
    assert all(tk.status == DONE for tk in tickets), [
        (tk.request.tenant, tk.status, tk.error) for tk in tickets if tk.status != DONE
    ]
    lat = np.array([tk.latency_s for tk in tickets])
    matches = int(sum(tk.count for tk in tickets))
    return {
        "requests": len(tickets),
        "tenants": tenants,
        "matches": matches,
        "wall_s": wall,
        "matches_per_s": matches / max(wall, 1e-9),
        "p50_s": float(np.percentile(lat, 50)),
        "p99_s": float(np.percentile(lat, 99)),
        "mean_s": float(lat.mean()),
        "peak_pool_cells": svc.peak_pool_cells,
        "ticks": svc.ticks,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=4, help="requests per tenant")
    ap.add_argument("--vertices", type=int, default=1 << 10)
    ap.add_argument("--deg", type=float, default=6.0)
    ap.add_argument("--max-active", type=int, default=4)
    ap.add_argument("--tick-steps", type=int, default=32)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 2 tenants, 1 request each, 256-vertex graph")
    ap.add_argument("--no-warmup", action="store_true")
    args = ap.parse_args(argv)

    if args.smoke:
        args.tenants, args.requests, args.vertices = 2, 1, 256
        args.no_warmup = True

    graph = bench_graph(args.vertices, args.deg, seed=7)
    if not args.no_warmup:
        run_load(graph, args.tenants, 1, args.max_active, args.tick_steps)

    out = run_load(graph, args.tenants, args.requests, args.max_active,
                   args.tick_steps)
    case = f"T{args.tenants}xR{args.requests}_v{args.vertices}"
    emit(f"service/{case}/p50_s", out["p50_s"] * 1e6, f"p99_s={out['p99_s']:.3f}")
    emit(f"service/{case}/matches_per_s", out["wall_s"] * 1e6 / max(out["requests"], 1),
         f"{out['matches_per_s']:.0f}")
    record_bench("service", [dict(
        suite="exp_service_load",
        case=case,
        mode="mixed-q1q3",
        **out,
    )])
    print(
        f"[service] {out['requests']} requests / {out['tenants']} tenants: "
        f"{out['matches']} matches, {out['matches_per_s']:,.0f} matches/s, "
        f"p50 {out['p50_s']:.3f}s, p99 {out['p99_s']:.3f}s "
        f"({out['ticks']} ticks, peak pool {out['peak_pool_cells']} cells)"
    )
    return out


if __name__ == "__main__":
    main()
