"""Perf-iteration helper: lower one cell, print roofline terms + per-op-kind
HBM byte breakdown + collectives. Writes JSON so iterations are diffable
(§Perf methodology: hypothesis → change → re-lower → compare).

    PYTHONPATH=src python -m benchmarks.perf_cell --arch rwkv6-7b --shape train_4k --tag baseline
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
import argparse
import json
import time

import jax

from repro.configs import SHAPES, get_config
from repro.launch import hlo_counter
from repro.launch.dryrun import _make_mesh
from repro.launch.mesh import DCI_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import input_specs
from repro.models import sharding as shd


def measure(arch: str, shape_name: str, mesh_kind: str, overrides=None):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    shape = SHAPES[shape_name]
    mesh = _make_mesh(mesh_kind == "multi")
    t0 = time.time()
    with shd.activate(mesh), mesh:
        cell = input_specs(cfg, shape, mesh)
        compiled = (
            jax.jit(cell.fn, in_shardings=cell.in_shardings,
                    donate_argnums=cell.donate_argnums)
            .lower(*cell.args)
            .compile()
        )
    txt = compiled.as_text()
    c = hlo_counter.analyze(txt)
    link = DCI_BW if mesh_kind == "multi" else ICI_BW
    mem = compiled.memory_analysis()
    out = {
        "meta": cell.meta,
        "compute_s": c.flops / PEAK_FLOPS_BF16,
        "memory_s": c.bytes / HBM_BW,
        "collective_s": c.coll_total / link,
        "flops": c.flops,
        "hbm_bytes": c.bytes,
        "coll": dict(c.coll),
        "coll_calls": dict(c.coll_calls),
        "by_kind": dict(sorted(c.by_kind.items(), key=lambda kv: -kv[1])[:12]),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "arg_bytes": int(mem.argument_size_in_bytes),
        "compile_s": round(time.time() - t0, 1),
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--out", default="reports/perf")
    args = ap.parse_args()
    r = measure(args.arch, args.shape, args.mesh)
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}__{args.mesh}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(r, f, indent=2)
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    print(json.dumps({k: v for k, v in r.items() if k not in ("by_kind", "coll_calls")}, indent=2))
    print("by_kind (TB):", {k: round(v / 1e12, 3) for k, v in r["by_kind"].items()})
    print("coll_calls:", r["coll_calls"])
    print(f"bound={bound:.2f}s  roofline_frac={r['compute_s'] / bound:.2%}")


if __name__ == "__main__":
    main()
