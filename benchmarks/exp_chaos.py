"""Chaos benchmark: recovery overhead of the fault-tolerance ladder.

For each query, a fault-free baseline run is timed against runs that recover
from an injected fault (queue-overflow → checkpoint restore at a halved
batch; shard-loss → deterministic replay; kernel-fail → one-shot ref-twin
fallback). Counts are asserted identical to the baseline before anything is
recorded, so every point in ``BENCH_chaos.json`` is a *successful* recovery —
the figure of merit is the wall-time overhead of surviving the fault
(EXPERIMENTS.md §Chaos).

  PYTHONPATH=src python -m benchmarks.exp_chaos            # default sweep
  PYTHONPATH=src python -m benchmarks.exp_chaos --smoke    # CI scale
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import bench_graph, emit, record_bench
from repro.core.engine import EngineConfig, HugeEngine
from repro.core.faults import FaultPlan
from repro.core.query import PAPER_QUERIES

# (case label, fault kind, extra EngineConfig fields)
FAULT_CASES = (
    ("queue-overflow", "queue-overflow", {}),
    ("shard-loss", "shard-loss", {}),
    ("kernel-fail", "kernel-fail", {"fused": True}),
)


def _cfg(seed: int, kind: str | None, **extra) -> EngineConfig:
    faults = None if kind is None else FaultPlan.single(
        kind, at_step=seed % 3, seed=seed)
    return EngineConfig(batch_size=256, queue_capacity=1 << 15,
                        join_buffer_capacity=1 << 17, faults=faults,
                        recover=True, **extra)


def run_case(graph, qname: str, kind: str | None, seed: int, **extra):
    eng = HugeEngine(graph, _cfg(seed, kind, **extra))
    t0 = time.perf_counter()
    res = eng.run(PAPER_QUERIES[qname])
    wall = time.perf_counter() - t0
    if kind is not None:
        fp = eng.cfg.faults
        assert fp.fired_count(kind) == 1, (
            f"{qname}/{kind}: fault never fired — not a recovery measurement")
    return res, wall


def main(argv=None) -> list:
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=1 << 11)
    ap.add_argument("--deg", type=float, default=6.0)
    ap.add_argument("--queries", nargs="+", default=["q1", "q2", "q3"])
    ap.add_argument("--seed", type=int, default=0,
                    help="FaultPlan seed (shifts the trigger step)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 512-vertex graph, q1 only")
    args = ap.parse_args(argv)

    if args.smoke:
        args.vertices, args.queries = 512, ["q1"]

    graph = bench_graph(args.vertices, args.deg, seed=7)
    entries = []
    for qname in args.queries:
        # Warmup run compiles every operator signature; the timed baseline
        # then measures steady-state, which is what recovery re-executes.
        run_case(graph, qname, None, args.seed)
        base, base_wall = run_case(graph, qname, None, args.seed)
        emit(f"chaos/{qname}/baseline", base_wall * 1e6, f"{base.count}m")
        for label, kind, extra in FAULT_CASES:
            if extra:
                # warm any extra-path signatures (e.g. fused kernels) so the
                # overhead measures recovery, not first-run compilation
                run_case(graph, qname, None, args.seed, **extra)
            res, wall = run_case(graph, qname, kind, args.seed, **extra)
            assert res.count == base.count, (
                f"{qname}/{label}: recovered count {res.count} != "
                f"baseline {base.count}")
            overhead = wall / max(base_wall, 1e-9)
            entries.append({
                "suite": "exp_chaos", "case": f"{qname}_{label}",
                "mode": "recovered", "matches": res.count,
                "wall_s": wall, "baseline_wall_s": base_wall,
                "overhead_x": overhead, "seed": args.seed,
                "retries": res.stats.retries,
                "restarts": res.stats.restarts,
                "pressure_events": res.stats.pressure_events,
                "kernel_fallbacks": res.stats.kernel_fallbacks,
            })
            emit(f"chaos/{qname}/{label}", wall * 1e6,
                 f"overhead={overhead:.2f}x")
            print(f"[chaos] {qname} {label}: recovered {res.count} matches "
                  f"in {wall:.2f}s vs baseline {base_wall:.2f}s "
                  f"({overhead:.2f}x)")
    record_bench("chaos", entries)
    worst = max(e["overhead_x"] for e in entries)
    print(f"[chaos] worst recovery overhead: {worst:.2f}x baseline")
    return entries


if __name__ == "__main__":
    main()
