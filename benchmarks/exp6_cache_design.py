"""Paper Exp-6: cache design comparison.

The paper compares LRBU vs copy/lock variants in wall time; locks don't exist
in a JAX SPMD program (the two-stage execution *is* the lock-freedom — see
DESIGN.md §Cache), so the comparable axis here is the replacement policy under the
same two-stage execution: LRBU (epoch-sealed) vs classic LRU vs direct-mapped.
Measured as hit rate / pulled bytes at equal capacity.
"""
from __future__ import annotations

from benchmarks.common import bench_graph, emit, run_query


def main():
    graph = bench_graph()
    for qname in ("q1", "q2", "q3"):
        for policy in ("lrbu", "lru", "direct"):
            res = run_query(graph, qname, cache_policy=policy, cache_capacity=1 << 12)
            s = res.stats
            emit(
                f"exp6/{policy}/{qname}",
                s.wall_time * 1e6,
                f"hit_rate={s.hit_rate:.3f};pulled={s.pulled_bytes / 1e6:.2f}MB;count={res.count}",
            )


if __name__ == "__main__":
    main()
