"""Paper Exp-10: scalability vs machine count.

Single-process CI box: we scale the *simulated* cluster size k and report the
communication totals and per-machine balance (the distributed-engine wall
clock scaling is measured separately by tests/test_distributed.py on 8 host
devices). Load balance std/mean mirrors the paper's Exp-8 metric.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_graph, emit
from repro.core.engine import EngineConfig, HugeEngine
from repro.core.query import PAPER_QUERIES


def main():
    graph = bench_graph()
    for qname in ("q1", "q2"):
        for k in (1, 2, 4, 8, 16):
            cfg = EngineConfig(num_machines=k, batch_size=1024, cache_capacity=1 << 13)
            eng = HugeEngine(graph, cfg, track_balance=True)
            res = eng.run(PAPER_QUERIES[qname])
            s = res.stats
            bal = s.per_machine_rows.astype(float)
            cv = float(bal.std() / max(bal.mean(), 1e-9)) if k > 1 else 0.0
            emit(
                f"exp10/k={k}/{qname}",
                s.wall_time * 1e6,
                f"C={s.total_comm_bytes / 1e6:.2f}MB;balance_cv={cv:.3f};count={res.count}",
            )


if __name__ == "__main__":
    main()
