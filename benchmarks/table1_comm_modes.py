"""Paper Table 1: square query across communication modes / systems.

Each prior system is its Table-2 plan space executed in our engine with its
own physical settings; HUGE is the full hybrid optimiser. We report the
paper's columns: T, T_R, T_C, C (bytes moved), M (peak queue memory) — at CI
scale (2^12-vertex power-law graph standing in for LJ).
"""
from __future__ import annotations

from benchmarks.common import bench_graph, emit, run_query


def main():
    graph = bench_graph()
    rows = []
    for system, space in [
        ("SEED", "seed"),
        ("BiGJoin", "bigjoin"),
        ("BENU", "benu"),
        ("RADS", "rads"),
        ("HUGE", "huge"),
    ]:
        res = run_query(graph, "q1", space=space)
        s = res.stats
        rows.append((system, res, s))
        emit(
            f"table1/{system}/q1",
            s.wall_time * 1e6,
            f"T={s.wall_time:.2f}s;T_R={s.compute_time:.2f}s;T_C={s.comm_time:.2f}s;"
            f"C={s.total_comm_bytes / 1e6:.2f}MB;M={s.peak_queue_bytes / 1e6:.2f}MB;"
            f"count={res.count}",
        )
    counts = {r[0]: r[1].count for r in rows}
    assert len(set(counts.values())) == 1, f"count mismatch across systems: {counts}"
    huge = rows[-1][2]
    best_push = min(r[2].total_comm_bytes for r in rows[:2])   # SEED, BiGJoin
    best_mem = min(r[2].peak_queue_bytes for r in rows[:2])
    emit(
        "table1/summary", 0.0,
        f"HUGE_comm_vs_best_push={best_push / max(huge.total_comm_bytes, 1):.1f}x;"
        f"HUGE_peakmem_vs_best_push={best_mem / max(huge.peak_queue_bytes, 1):.1f}x;"
        "note=wall-clock at CI scale is compile-dominated, bytes/memory are the "
        "paper-comparable columns (BENU's pull volume matches HUGE by design; its "
        "paper penalty was external-store overhead)",
    )


if __name__ == "__main__":
    main()
