"""Paper Exp-5: cache capacity sweep — hit rate and pulled bytes vs capacity."""
from __future__ import annotations

from benchmarks.common import bench_graph, emit, run_query


def main():
    graph = bench_graph()
    for qname in ("q1", "q2"):
        for cap in (0, 1 << 10, 1 << 12, 1 << 14, 1 << 16):
            res = run_query(graph, qname, cache_capacity=cap)
            s = res.stats
            emit(
                f"exp5/cache={cap}/{qname}",
                s.wall_time * 1e6,
                f"hit_rate={s.hit_rate:.3f};pulled={s.pulled_bytes / 1e6:.2f}MB;count={res.count}",
            )


if __name__ == "__main__":
    main()
