"""Benchmark harness entry point — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``           (all, CSV to stdout)
``PYTHONPATH=src python -m benchmarks.run table1``    (one table)
``PYTHONPATH=src python -m benchmarks.run --list``    (print the registry)

Each function prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

The registry is self-checking: every ``exp*.py`` / ``table*.py`` module in
this package must appear in ``SUITES`` exactly once (and every registered
module must exist on disk), so a new experiment file can't be silently
orphaned from ``--all`` runs — the harness refuses to start instead.
"""
from __future__ import annotations

import inspect
import os
import sys
import time
from typing import List

from benchmarks import (
    exp1_plugin_plans,
    exp4_batching,
    exp5_cache,
    exp6_cache_design,
    exp7_scheduling,
    exp9_plans,
    exp10_scaling,
    exp_chaos,
    exp_dist_hybrid,
    exp_service_load,
    exp_streaming,
    table1_comm_modes,
    table4_throughput,
)

SUITES = {
    "table1": table1_comm_modes,
    "exp1": exp1_plugin_plans,
    "exp4": exp4_batching,
    "exp5": exp5_cache,
    "exp6": exp6_cache_design,
    "exp7": exp7_scheduling,
    "exp9": exp9_plans,
    "exp10": exp10_scaling,
    "exp_chaos": exp_chaos,
    "exp_dist_hybrid": exp_dist_hybrid,
    "exp_service_load": exp_service_load,
    "exp_streaming": exp_streaming,
    "table4": table4_throughput,
}


def _run_suite(mod) -> None:
    sig = inspect.signature(mod.main)
    if sig.parameters:
        # argv pinned to [] so the harness's own CLI words don't leak into
        # the suite's argparse
        mod.main([])
    else:
        mod.main()


def registry_problems() -> List[str]:
    """Every ``exp*``/``table*`` module on disk registered exactly once."""
    bench_dir = os.path.dirname(os.path.abspath(__file__))
    on_disk = sorted(
        f[: -len(".py")]
        for f in os.listdir(bench_dir)
        if f.endswith(".py") and (f.startswith("exp") or f.startswith("table"))
    )
    registered = [m.__name__.rsplit(".", 1)[-1] for m in SUITES.values()]
    problems = []
    for mod in on_disk:
        n = registered.count(mod)
        if n == 0:
            problems.append(f"benchmarks/{mod}.py is not registered in SUITES")
        elif n > 1:
            problems.append(f"benchmarks/{mod}.py is registered {n} times")
    for mod in registered:
        if mod not in on_disk:
            problems.append(f"SUITES entry {mod!r} has no benchmarks/{mod}.py")
    return problems


def main(argv: List[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    problems = registry_problems()
    if problems:
        for p in problems:
            print(f"registry error: {p}", file=sys.stderr)
        return 2
    if "--list" in argv:
        for name, mod in SUITES.items():
            print(f"{name:18s} benchmarks/{mod.__name__.rsplit('.', 1)[-1]}.py")
        return 0
    wanted = [a for a in argv if not a.startswith("-")] or list(SUITES)
    unknown = [w for w in wanted if w not in SUITES]
    if unknown:
        print(f"unknown suite(s): {', '.join(unknown)} "
              f"(--list prints the registry)", file=sys.stderr)
        return 2
    print("name,us_per_call,derived")
    for name in wanted:
        t0 = time.time()
        try:
            _run_suite(SUITES[name])
        except Exception as e:  # noqa: BLE001 — keep the suite running
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
        print(f"{name}/_suite_wall,{(time.time() - t0) * 1e6:.0f},done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
