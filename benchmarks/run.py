"""Benchmark harness entry point — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``           (all, CSV to stdout)
``PYTHONPATH=src python -m benchmarks.run table1``    (one table)

Each function prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
from __future__ import annotations

import sys
import time

from benchmarks import (
    exp1_plugin_plans,
    exp4_batching,
    exp5_cache,
    exp6_cache_design,
    exp7_scheduling,
    exp9_plans,
    exp10_scaling,
    exp_dist_hybrid,
    exp_service_load,
    table1_comm_modes,
    table4_throughput,
)

SUITES = {
    "table1": table1_comm_modes.main,
    "exp1": exp1_plugin_plans.main,
    "exp4": exp4_batching.main,
    "exp5": exp5_cache.main,
    "exp6": exp6_cache_design.main,
    "exp7": exp7_scheduling.main,
    "exp9": exp9_plans.main,
    "exp10": exp10_scaling.main,
    "exp_dist_hybrid": exp_dist_hybrid.main,
    # argv pinned to [] so the harness's own CLI words don't leak into the
    # suite's argparse
    "exp_service_load": lambda: exp_service_load.main([]),
    "table4": table4_throughput.main,
}


def main() -> None:
    wanted = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    for name in wanted:
        t0 = time.time()
        try:
            SUITES[name]()
        except Exception as e:  # noqa: BLE001 — keep the suite running
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
        print(f"{name}/_suite_wall,{(time.time() - t0) * 1e6:.0f},done")


if __name__ == "__main__":
    main()
