"""Paper Exp-1: plug existing systems' *logical* plans into HUGE.

Remark 3.2: feed each prior system's logical plan through HUGE's physical
configuration (Eq. 3) and compare against the same logical plan under the
system's own physical settings — the speedup is HUGE's hybrid communication +
engine, cost model and scheduling held fixed.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import bench_graph, emit, run_query
from repro.core.plan import PLAN_SPACES, PlanSpace


def _hugeified(space_name: str) -> PlanSpace:
    """Same logical space (units/order), HUGE's physical freedom (Eq. 3)."""
    base = PLAN_SPACES[space_name]
    return dataclasses.replace(
        base, name=f"huge-{space_name}", algos=("hash", "wco"), comms=("push", "pull")
    )


def main():
    graph = bench_graph()
    for qname in ("q1", "q2"):
        for system in ("benu", "rads", "seed", "bigjoin"):
            native = run_query(graph, qname, space=system)
            hugeed = run_query(graph, qname, space=_hugeified(system))
            assert native.count == hugeed.count, (system, qname)
            speed = native.stats.wall_time / max(hugeed.stats.wall_time, 1e-9)
            comm = native.stats.total_comm_bytes / max(hugeed.stats.total_comm_bytes, 1)
            emit(
                f"exp1/HUGE-{system.upper()}/{qname}",
                hugeed.stats.wall_time * 1e6,
                f"speedup={speed:.2f}x;comm_reduction={comm:.2f}x;count={hugeed.count}",
            )


if __name__ == "__main__":
    main()
