"""Shared benchmark fixtures: graphs, queries, engine runners, CSV output."""
from __future__ import annotations

import functools
import json
import os
import subprocess
import tempfile
import time
from typing import Dict, List, Optional

from repro.core.cost import GraphStats
from repro.core.dataflow import translate
from repro.core.engine import EngineConfig, EnumerationResult, HugeEngine
from repro.core.optimizer import optimal_plan
from repro.core.query import PAPER_QUERIES
from repro.graph import powerlaw_graph, erdos_renyi

_GRAPH_CACHE: Dict = {}


def bench_graph(n: int = 1 << 11, deg: float = 6.0, seed: int = 7, kind: str = "powerlaw"):
    key = (n, deg, seed, kind)
    if key not in _GRAPH_CACHE:
        gen = powerlaw_graph if kind == "powerlaw" else erdos_renyi
        _GRAPH_CACHE[key] = gen(n, deg, seed=seed)
    return _GRAPH_CACHE[key]


def run_query(
    graph,
    qname: str,
    space: str = "huge",
    machines: int = 8,
    batch_size: int = 512,
    queue_capacity: int = 1 << 16,
    cache_capacity: int = 1 << 13,
    cache_policy: str = "lrbu",
    join_out_capacity: int = 1 << 18,
    fused: bool = False,
) -> EnumerationResult:
    """CI-scale single run. jit caches are process-global, so within a suite
    the first run of each operator signature pays compile and the rest are
    steady-state — relative comparisons (the paper's point) hold."""
    query = PAPER_QUERIES[qname]
    cfg = EngineConfig(
        batch_size=batch_size,
        queue_capacity=queue_capacity,
        cache_capacity=cache_capacity,
        cache_policy=cache_policy,
        num_machines=machines,
        join_out_capacity=join_out_capacity,
        join_buffer_capacity=1 << 21,
        fused=fused,
    )
    plan = optimal_plan(query, GraphStats.from_graph(graph), machines, space)
    flow = translate(plan)
    engine = HugeEngine(graph, cfg)
    return engine.run(flow)


def emit(name: str, us_per_call: float, derived: str):
    """One CSV row per benchmark result: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@functools.lru_cache(maxsize=1)
def flowcheck_rule_count() -> int:
    """Error count from the clean-tree flowcheck corpus (0 on a healthy
    tree; -1 when the verifier itself failed to run). Stamped on every
    recorded bench entry so a trajectory point produced on a tree whose
    plans don't verify is visibly tainted."""
    try:
        from repro.analysis import clean_tree_flowcheck

        return len([d for d in clean_tree_flowcheck() if d.severity == "error"])
    except Exception:  # noqa: BLE001 — the stamp must never sink a bench run
        return -1


@functools.lru_cache(maxsize=1)
def git_rev() -> str:
    """Short git revision of the repo (``unknown`` outside a checkout)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=REPO_ROOT, capture_output=True, text=True, timeout=10,
        )
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else "unknown"
    except OSError:
        return "unknown"


def record_bench(name: str, entries: List[dict]) -> str:
    """Append trajectory points to ``BENCH_<name>.json`` at the repo root.

    Entry format (EXPERIMENTS.md §Perf): each point carries ``suite``,
    ``case``, ``mode``, ``matches``, ``wall_s``, ``matches_per_s``; this
    helper stamps ``recorded`` (ISO-8601 timestamp), ``git`` (short rev),
    and ``flowcheck_rules`` (clean-tree verifier error count — 0 expected)
    so successive PRs accumulate an *attributable* regression trajectory
    instead of overwriting it.

    The write is crash-safe: the merged document goes to a temp file in the
    same directory and is renamed over the target (``os.replace`` is atomic
    on POSIX), so a benchmark process killed mid-write — e.g. by the chaos
    harness — can never leave a truncated JSON behind. If a previous crash
    *did* corrupt the file (pre-atomic histories), the corrupt bytes are
    preserved in a ``.corrupt`` sidecar and the trajectory restarts rather
    than sinking every future bench run."""
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    doc = {"bench": name, "entries": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
            if not isinstance(doc, dict):
                raise ValueError(f"expected a JSON object, got {type(doc)}")
        except (json.JSONDecodeError, ValueError, UnicodeDecodeError):
            sidecar = path + ".corrupt"
            os.replace(path, sidecar)
            print(f"record_bench: {path} was corrupt; preserved as {sidecar} "
                  "and starting a fresh trajectory")
            doc = {"bench": name, "entries": []}
    stamp = time.strftime("%Y-%m-%dT%H:%M:%S%z")
    doc["updated"] = stamp
    doc.setdefault("entries", []).extend(
        [dict(e, recorded=stamp, git=git_rev(),
              flowcheck_rules=flowcheck_rule_count()) for e in entries]
    )
    fd, tmp = tempfile.mkstemp(
        dir=REPO_ROOT, prefix=f".BENCH_{name}.", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        os.chmod(tmp, 0o644)  # mkstemp defaults to 0600
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path
