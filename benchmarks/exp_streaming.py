"""Streaming benchmark: delta-plan enumeration vs. full re-enumeration.

The ROADMAP streaming item made concrete: a power-law graph receives a
stream of edge-insert batches; after every batch a standing query's new
matches must be delivered. The incremental path applies the batch with the
row-local ``apply_updates`` and runs the k-flow delta decomposition
(``run_delta``); the baseline re-enumerates the whole post-batch graph and
diffs. Both deliver the same new matches, so the figure of merit is
*new-matches/sec* per path — delta work scales with the batch, full work
with the graph, so the advantage grows as batches shrink (EXPERIMENTS.md
§Streaming; the acceptance bar is ≥5× at the small batch size).

  PYTHONPATH=src python -m benchmarks.exp_streaming            # default sweep
  PYTHONPATH=src python -m benchmarks.exp_streaming --smoke    # CI scale

Per (query, batch-size) case the stream's first batch is a discarded warmup
(jit compile for both paths); timed batches check that the summed delta
counts equal the full-enumeration diff before recording anything.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import bench_graph, emit, record_bench
from repro.core.engine import EngineConfig, HugeEngine
from repro.core.query import PAPER_QUERIES
from repro.graph import build_graph
from repro.graph.storage import GraphUpdateBatch


def undirected_edges(graph) -> np.ndarray:
    """Extract the undirected edge array ``int[E, 2]`` from a built graph."""
    offs = np.asarray(graph.offsets)
    nbrs = np.asarray(graph.nbrs)
    src = np.repeat(np.arange(graph.num_vertices), np.diff(offs))
    und = np.stack([src, nbrs], axis=1)
    return und[und[:, 0] < und[:, 1]]


def run_case(und: np.ndarray, n: int, qname: str, batch_edges: int,
             batches: int, cfg: EngineConfig, seed: int) -> dict:
    """Stream ``batches`` batches of ``batch_edges`` edges onto a base graph;
    time delta enumeration vs. full re-enumeration after each batch."""
    rng = np.random.default_rng(seed)
    und = und[rng.permutation(len(und))]
    tail = (batches + 1) * batch_edges  # +1: warmup batch
    base, stream = und[:-tail], und[-tail:]
    chunks = np.array_split(stream, batches + 1)

    q = PAPER_QUERIES[qname]
    eng = HugeEngine(build_graph(base, n), cfg)

    # The baseline re-enumerates from scratch, so it pays a fresh engine
    # (planning, scan arrays, caches) per batch — exactly what a non-
    # incremental deployment would do. Engine stats are cumulative per
    # engine, so the baseline needs a fresh one for a per-batch count anyway.
    def full_count(graph):
        return HugeEngine(graph, cfg).run(q).count

    # Warmup batch: compiles both paths; its counts are excluded below.
    eng.apply_updates(GraphUpdateBatch(chunks[0]))
    eng.run_delta(q)
    c_prev = full_count(eng.graph)

    delta_s = full_s = apply_s = 0.0
    new_matches = 0
    for chunk in chunks[1:]:
        t0 = time.perf_counter()
        eng.apply_updates(GraphUpdateBatch(chunk))
        apply_s += time.perf_counter() - t0

        t0 = time.perf_counter()
        r = eng.run_delta(q)
        delta_s += time.perf_counter() - t0
        new_matches += r.count

        t0 = time.perf_counter()
        c_now = full_count(eng.graph)
        full_s += time.perf_counter() - t0

    assert new_matches == c_now - c_prev, (qname, new_matches, c_now - c_prev)
    return {
        "query": qname,
        "batch_edges": batch_edges,
        "batches": batches,
        "vertices": n,
        "new_matches": new_matches,
        "delta_s": delta_s,
        "full_s": full_s,
        "apply_s": apply_s,
        "delta_matches_per_s": new_matches / max(delta_s, 1e-9),
        "full_matches_per_s": new_matches / max(full_s, 1e-9),
        "speedup": full_s / max(delta_s, 1e-9),
    }


def main(argv=None) -> list:
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=1 << 12)
    ap.add_argument("--deg", type=float, default=6.0)
    ap.add_argument("--queries", nargs="+", default=["q1", "q2"])
    ap.add_argument("--batch-edges", nargs="+", type=int, default=[8, 64])
    ap.add_argument("--batches", type=int, default=3,
                    help="timed batches per case (one warmup batch on top)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 512-vertex graph, q1, one small batch size")
    args = ap.parse_args(argv)

    if args.smoke:
        args.vertices, args.queries = 512, ["q1"]
        args.batch_edges, args.batches = [8], 2

    graph = bench_graph(args.vertices, args.deg, seed=7)
    und = undirected_edges(graph)
    cfg = EngineConfig(batch_size=256, materialize=False)

    entries = []
    for qname in args.queries:
        for b in args.batch_edges:
            out = run_case(und, args.vertices, qname, b, args.batches, cfg,
                           seed=100 + b)
            entries.append(dict(suite="exp_streaming", case=f"{qname}_b{b}", **out))
            emit(f"streaming/{qname}_b{b}/delta", out["delta_s"] * 1e6 / args.batches,
                 f"{out['delta_matches_per_s']:.0f}mps")
            emit(f"streaming/{qname}_b{b}/full", out["full_s"] * 1e6 / args.batches,
                 f"speedup={out['speedup']:.1f}x")
            print(
                f"[streaming] {qname} batch={b}: {out['new_matches']} new matches, "
                f"delta {out['delta_matches_per_s']:,.0f}/s vs full "
                f"{out['full_matches_per_s']:,.0f}/s → {out['speedup']:.1f}x "
                f"(apply {out['apply_s'] * 1e3:.1f}ms total)"
            )
    record_bench("streaming", entries)

    small = min(e["batch_edges"] for e in entries)
    worst = min(e["speedup"] for e in entries if e["batch_edges"] == small)
    print(f"[streaming] min speedup at batch={small}: {worst:.1f}x")
    return entries


if __name__ == "__main__":
    main()
