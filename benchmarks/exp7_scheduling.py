"""Paper Exp-7 (Figure 9): queue size sweeps DFS ↔ adaptive ↔ BFS.

Queue capacity 1 batch ≈ DFS; huge ≈ BFS. We report wall time and peak queue
memory; the paper's OOM at the BFS end appears here as peak memory growth
(bounded only by the preallocated capacity — allocation failure on real HW).
"""
from __future__ import annotations

from benchmarks.common import bench_graph, emit, run_query


def main():
    graph = bench_graph()
    qname = "q1"
    for qcap in (1 << 10, 1 << 13, 1 << 15, 1 << 17, 1 << 19):
        res = run_query(graph, qname, queue_capacity=qcap, batch_size=256)
        s = res.stats
        emit(
            f"exp7/queue={qcap}/{qname}",
            s.wall_time * 1e6,
            f"peakM={s.peak_queue_bytes / 1e6:.2f}MB;steps={res.schedule.steps};"
            f"yields_full={res.schedule.yields_full};count={res.count}",
        )


if __name__ == "__main__":
    main()
