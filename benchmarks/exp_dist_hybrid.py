"""Distributed Table-1: communication modes with *real* collectives.

table1_comm_modes.py reproduces the paper's communication-mode comparison
with simulated byte accounting on the single-process engine; this suite runs
the same comparison on the shard_map SPMD engine (distributed.py), where the
bytes are what ``all_to_all`` collectives actually moved:

  pull-only  BENU space: pure extend/verify chains, GetNbrs fetch traffic;
  push-only  SEED space: hash/push plans — every join is a distributed
             PUSH-JOIN hash shuffle;
  hybrid     HUGE space: the optimiser mixes PULL-EXTEND and PUSH-JOIN
             per Eq. 3 (the paper's headline claim).

Per row we report count, pull bytes (fetch-stage remote vids × (D_pad+2)·4),
push bytes (join-shuffle rows crossing shards × row width), steal bytes, and
the Eq.-3 prediction from hybrid_comm.enum_join_mode for context.

XLA fixes the host device count at import, so the measurement runs in a
fresh interpreter with ``--xla_force_host_platform_device_count=8`` (same
mechanism as tests/test_distributed.py); invoke via
``PYTHONPATH=src python -m benchmarks.run exp_dist_hybrid`` (EXPERIMENTS.md
§Distributed-hybrid).
"""
from __future__ import annotations

import os
import subprocess
import sys

SHARDS = 8
QUERIES = ("q1", "q2")  # q7+ explode at CI scale; run them via launch/enumerate
SYSTEMS = (("pull-only", "benu"), ("push-only", "seed"), ("hybrid", "huge"))


def inner() -> None:
    import time

    import jax

    from benchmarks.common import emit, record_bench
    from repro.core import query as Q
    from repro.core.cost import GraphStats
    from repro.core.distributed import DistConfig, DistributedEngine
    from repro.core.hybrid_comm import enum_join_mode
    from repro.graph import powerlaw_graph

    mesh = jax.make_mesh((SHARDS,), ("shards",))
    graph = powerlaw_graph(1 << 9, 6.0, seed=7)
    stats = GraphStats.from_graph(graph)
    engines = {
        False: DistributedEngine(
            graph, mesh, DistConfig(batch_size=256, queue_capacity=1 << 15)
        ),
        True: DistributedEngine(
            graph, mesh,
            DistConfig(batch_size=256, queue_capacity=1 << 15, fused=True),
        ),
    }
    entries = []
    for qname in QUERIES:
        q = Q.PAPER_QUERIES[qname]
        counts = {}
        for system, space in SYSTEMS:
            for fused in (False, True):
                t0 = time.perf_counter()
                count, s = engines[fused].run(q, space=space)
                wall = time.perf_counter() - t0
                counts[(system, fused)] = count
                assert s["engine"] == "shard_map"
                mode = "fused" if fused else "unfused"
                emit(
                    f"exp_dist_hybrid/{system}/{qname}"
                    + ("/fused" if fused else ""),
                    wall * 1e6,
                    f"count={count};joins={s['joins']};a2a={s['a2a_calls']};"
                    f"pull={s['pulled_bytes'] / 1e6:.3f}MB;"
                    f"push={s['shuffle_bytes'] / 1e6:.3f}MB;"
                    f"steal={s['steal_bytes'] / 1e6:.3f}MB",
                )
                entries.append({
                    "suite": "exp_dist_hybrid", "case": f"{system}/{qname}",
                    "mode": mode, "matches": int(count),
                    "wall_s": round(wall, 4),
                    "matches_per_s": round(count / max(wall, 1e-9), 1),
                })
        assert len(set(counts.values())) == 1, f"{qname}: {counts}"
        # Eq.-3 prediction for this query's top-level join volume: use the
        # total match count as the intermediate-result proxy (CI scale).
        hybrid_count = counts[("hybrid", False)]
        dec = enum_join_mode(
            left_rows=max(hybrid_count, 1), right_rows=max(hybrid_count, 1),
            width_left=q.num_vertices, width_right=q.num_vertices,
            graph_edges=stats.num_directed_edges / 2, machines=SHARDS,
        )
        emit(
            f"exp_dist_hybrid/eq3/{qname}", 0.0,
            f"mode={dec.mode};push={dec.push_bytes / 1e6:.3f}MB;"
            f"pull={dec.pull_bytes / 1e6:.3f}MB",
        )
    path = record_bench("fused_hotpath", entries)
    print(f"# wrote {path}")


def main() -> None:
    """Relay the measurement from a fresh interpreter with 8 host devices."""
    env = dict(
        os.environ,
        PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
    )
    r = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.exp_dist_hybrid import inner; inner()"],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(f"exp_dist_hybrid subprocess failed:\n{r.stderr[-3000:]}")


if __name__ == "__main__":
    main()
