"""Paper Table 4: enumeration throughput (matches/second) on the largest
CI-scale graph, queries q1-q3 — in both ``fused=`` modes. Counts must be
identical; the fused/unfused matches-per-second pair is appended to
``BENCH_fused_hotpath.json`` (EXPERIMENTS.md §Perf)."""
from __future__ import annotations

from benchmarks.common import bench_graph, emit, record_bench, run_query


def main():
    graph = bench_graph(n=1 << 12, deg=8.0)
    entries = []
    for qname in ("q1", "q2", "q3"):
        counts = {}
        for fused in (False, True):
            res = run_query(
                graph, qname, batch_size=1024, queue_capacity=1 << 17, fused=fused
            )
            s = res.stats
            thr = res.count / max(s.wall_time, 1e-9)
            mode = "fused" if fused else "unfused"
            counts[mode] = res.count
            emit(
                f"table4/{qname}" + ("/fused" if fused else ""),
                s.wall_time * 1e6,
                f"throughput={thr:,.0f}/s;count={res.count};M={s.peak_queue_bytes / 1e6:.1f}MB",
            )
            entries.append({
                "suite": "table4_throughput", "case": qname, "mode": mode,
                "matches": int(res.count), "wall_s": round(s.wall_time, 4),
                "matches_per_s": round(thr, 1),
            })
        assert counts["fused"] == counts["unfused"], (qname, counts)
    path = record_bench("fused_hotpath", entries)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
