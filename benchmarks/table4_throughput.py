"""Paper Table 4: enumeration throughput (matches/second) on the largest
CI-scale graph, queries q1-q3."""
from __future__ import annotations

from benchmarks.common import bench_graph, emit, run_query


def main():
    graph = bench_graph(n=1 << 12, deg=8.0)
    for qname in ("q1", "q2", "q3"):
        res = run_query(graph, qname, batch_size=1024, queue_capacity=1 << 17)
        s = res.stats
        thr = res.count / max(s.wall_time, 1e-9)
        emit(
            f"table4/{qname}",
            s.wall_time * 1e6,
            f"throughput={thr:,.0f}/s;count={res.count};M={s.peak_queue_bytes / 1e6:.1f}MB",
        )


if __name__ == "__main__":
    main()
