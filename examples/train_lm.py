"""End-to-end driver: train a ~100M-parameter granite-style LM for a few
hundred steps on CPU, with checkpointing and the adaptive microbatch scheduler.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(~100M params: 12 layers × d_model 512 on the granite backbone; on a real pod
drop --smoke-dims and point --arch at any of the 10 assigned configs.)
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: reduce granite-3-8b's depth/width but keep its shape.
    import repro.configs.granite_3_8b as g

    base = g.config()
    cfg100m = base.scaled(
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32064, attn_chunk=128,
    )
    # monkey-patch the smoke config so the driver picks it up
    g.smoke = lambda: cfg100m

    train_main([
        "--arch", "granite-3-8b", "--smoke",
        "--steps", str(args.steps),
        "--global-batch", "8", "--seq-len", "128",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--lr", "6e-4", "--log-every", "10",
    ])


if __name__ == "__main__":
    main()
