"""Batched serving example: prefill + slot-based continuous decode.

    PYTHONPATH=src python examples/serve_batch.py --arch rwkv6-7b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-7b")
    args = ap.parse_args()
    serve_main([
        "--arch", args.arch, "--smoke",
        "--requests", "12", "--prompt-len", "24", "--max-new", "24",
        "--slots", "4", "--temperature", "0.8",
    ])


if __name__ == "__main__":
    main()
