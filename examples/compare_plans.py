"""Plan-space comparison (the paper's Exp-1/Exp-9 in example form): run the
same query under every prior system's plan space and print the Table-1-style
breakdown.

    PYTHONPATH=src python examples/compare_plans.py --query q1
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core.cost import GraphStats
from repro.core.engine import EngineConfig, HugeEngine
from repro.core.optimizer import optimal_plan
from repro.core.query import PAPER_QUERIES
from repro.graph import powerlaw_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", default="q1", choices=list(PAPER_QUERIES))
    ap.add_argument("--vertices", type=int, default=4096)
    args = ap.parse_args()

    graph = powerlaw_graph(args.vertices, 8.0, seed=7)
    query = PAPER_QUERIES[args.query]
    stats = GraphStats.from_graph(graph)
    print(f"{'system':10s} {'T':>8s} {'T_R':>8s} {'T_C':>8s} {'C(MB)':>8s} {'M(MB)':>8s} {'count':>10s}")
    for system in ("starjoin", "seed", "bigjoin", "benu", "rads", "huge"):
        plan = optimal_plan(query, stats, 8, system)
        res = HugeEngine(graph, EngineConfig(num_machines=8)).run(plan)
        s = res.stats
        print(
            f"{system:10s} {s.wall_time:8.2f} {s.compute_time:8.2f} {s.comm_time:8.2f} "
            f"{s.total_comm_bytes / 1e6:8.2f} {s.peak_queue_bytes / 1e6:8.2f} {res.count:>10,}"
        )


if __name__ == "__main__":
    main()
