"""Quickstart: enumerate subgraphs with HUGE in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.engine import EngineConfig, enumerate_query
from repro.core.query import PAPER_QUERIES, clique
from repro.graph import powerlaw_graph

# 1. A data graph (here: synthetic power-law; swap in your own edge list via
#    repro.graph.from_edge_list).
graph = powerlaw_graph(num_vertices=2048, avg_degree=6.0, seed=0)
print(f"graph: |V|={graph.num_vertices} |E|={graph.num_edges} d_max={graph.max_degree}")

# 2. A query pattern — the paper's q1 (square) and a 4-clique.
for query in (PAPER_QUERIES["q1"], clique(4)):
    # 3. One call: optimiser (Alg. 1) → dataflow (Alg. 2) → BFS/DFS-adaptive
    #    scheduler (Alg. 5) → count, with communication accounting.
    res = enumerate_query(graph, query, EngineConfig(num_machines=8))
    s = res.stats
    print(
        f"{query.name:10s} count={res.count:>10,}  "
        f"T={s.wall_time:.2f}s (compute {s.compute_time:.2f}s / comm {s.comm_time:.2f}s)  "
        f"pulled={s.pulled_bytes / 1e6:.1f}MB pushed={s.pushed_bytes / 1e6:.1f}MB "
        f"cache-hits={s.hit_rate:.0%}  peak-mem={s.peak_queue_bytes / 1e6:.1f}MB"
    )
