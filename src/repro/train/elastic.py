"""Elastic scaling: reshard any checkpoint onto a different mesh.

Checkpoints store logically-global arrays (mesh-agnostic); resharding is a
``device_put`` onto the new mesh's NamedShardings. Shrink (lost pod / fewer
hosts) and grow both reduce to the same operation — the training driver calls
``reshard_checkpoint`` at startup with whatever devices it finds.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

from repro.models import transformer as T
from repro.models.partitioning import param_shardings
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, state_shapes
from repro.train.train_step import TrainConfig


def make_mesh_from_available(model_axis: int = 1) -> Mesh:
    """Build a (data, model) mesh from whatever devices exist right now."""
    devs = jax.devices()
    n = len(devs)
    assert n % model_axis == 0, (n, model_axis)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def reshard_checkpoint(
    ckpt_dir: str,
    step: int,
    cfg_model: T.ModelConfig,
    cfg_train: TrainConfig,
    mesh: Mesh,
    *,
    fsdp: bool = False,
) -> Tuple[object, object, dict]:
    """Load checkpoint ``step`` and place it on ``mesh`` (any device count)."""
    p_like = T.param_shapes(cfg_model)
    o_like = state_shapes(cfg_train.adamw, p_like)
    p_sh = param_shardings(cfg_model, p_like, mesh, fsdp=fsdp)
    o_sh = {
        "m": param_shardings(cfg_model, p_like, mesh, fsdp=fsdp),
        "v": param_shardings(cfg_model, p_like, mesh, fsdp=fsdp),
        "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }
    return ckpt.load(ckpt_dir, step, p_like, o_like, shardings=(p_sh, o_sh))
