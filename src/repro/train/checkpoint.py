"""Fault-tolerant checkpointing: sharded, atomic, digest-verified, async.

Layout:  <dir>/step_<N>/
            manifest.json    {step, leaf paths, shapes, dtypes, digest, mesh}
            arrays.npz       one entry per leaf (flattened path key)

Writes go to ``step_<N>.tmp`` and are atomically renamed — a crash mid-write
never corrupts the latest checkpoint. ``latest_step`` skips entries whose
digest fails, so restart survives partially-written or corrupted directories
(tested by the failure-injection tests). ``save_async`` runs serialisation on
a daemon thread off the training critical path.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy's npz cannot represent bfloat16 — encode as uint16 and record the
# true dtype in the manifest.
_ENCODE = {np.dtype(ml_dtypes.bfloat16): np.uint16}
_DECODE = {"bfloat16": ml_dtypes.bfloat16}


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _encode(arrays: Dict[str, np.ndarray]):
    enc, dtypes = {}, {}
    for k, v in arrays.items():
        dtypes[k] = str(v.dtype)
        enc[k] = v.view(_ENCODE[v.dtype]) if v.dtype in _ENCODE else v
    return enc, dtypes


def _decode(arrays: Dict[str, np.ndarray], dtypes: Dict[str, str]):
    out = {}
    for k, v in arrays.items():
        want = dtypes.get(k, str(v.dtype))
        out[k] = v.view(_DECODE[want]) if want in _DECODE else v
    return out


def _digest(arrays: Dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes()[: 1 << 20])
    return h.hexdigest()


def save(ckpt_dir: str, step: int, params, opt_state, extra: Optional[Dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = {f"params/{k}": v for k, v in _flatten(params).items()}
    arrays.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    enc, dtypes = _encode(arrays)
    np.savez(os.path.join(tmp, "arrays.npz"), **enc)
    manifest = {
        "step": step,
        "digest": _digest(enc),
        "keys": sorted(arrays.keys()),
        "dtypes": dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


_pending: Dict[str, threading.Thread] = {}


def save_async(ckpt_dir: str, step: int, params, opt_state, extra=None) -> threading.Thread:
    # Pull to host on the caller (cheap on CPU; device→host copy elsewhere)
    params_h = jax.tree.map(np.asarray, params)
    opt_h = jax.tree.map(np.asarray, opt_state)
    th = threading.Thread(
        target=save, args=(ckpt_dir, step, params_h, opt_h, extra), daemon=True
    )
    th.start()
    _pending[ckpt_dir] = th
    return th


def wait_pending(ckpt_dir: str):
    th = _pending.get(ckpt_dir)
    if th is not None:
        th.join()


def _verify(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        if sorted(arrays.keys()) != manifest["keys"]:
            return False
        return _digest(arrays) == manifest["digest"]
    except Exception:
        return False


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Largest step with a *valid* checkpoint (corrupt/partial ones skipped)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    for step in sorted(steps, reverse=True):
        if _verify(os.path.join(ckpt_dir, f"step_{step:08d}")):
            return step
    return None


def load(ckpt_dir: str, step: int, params_like, opt_like, shardings=None) -> Tuple[Any, Any, Dict]:
    """Restore onto the template trees; ``shardings`` (same structure) places
    leaves onto a (possibly different) mesh — this is the elastic-resharding
    entry point."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = _decode({k: z[k] for k in z.files}, manifest.get("dtypes", {}))

    def rebuild(tree, prefix, shard_tree=None):
        flat_paths = jax.tree_util.tree_flatten_with_path(tree)
        leaves = []
        shard_leaves = (
            jax.tree.leaves(shard_tree) if shard_tree is not None else [None] * len(flat_paths[0])
        )
        for (path_k, leaf), sh in zip(flat_paths[0], shard_leaves):
            key = prefix + "/".join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in path_k
            )
            arr = arrays[key]
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(flat_paths[1], leaves)

    p_sh, o_sh = (shardings if shardings is not None else (None, None))
    params = rebuild(params_like, "params/", p_sh)
    opt = rebuild(opt_like, "opt/", o_sh)
    return params, opt, manifest.get("extra", {})
