"""Synthetic deterministic data pipeline with prefetch + straggler backup.

Tokens are Zipf-distributed (vocab skew like natural text) and fully
determined by (seed, step), so restart-resume reproduces the exact stream —
the property checkpoint/restart tests rely on. A prefetch thread keeps
``depth`` batches ready; if the pipeline ever stalls past ``timeout_s`` the
loader re-serves the last good batch (backup-batch straggler mitigation) and
counts the event.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    microbatches: int = 1
    seed: int = 0
    zipf_a: float = 1.3
    frontend_len: int = 0
    d_model: int = 0
    frontend: Optional[str] = None
    prefetch_depth: int = 2
    timeout_s: float = 30.0


def synth_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    shape = (cfg.global_batch, cfg.seq_len)
    toks = rng.zipf(cfg.zipf_a, size=shape).astype(np.int64)
    toks = np.clip(toks - 1, 0, cfg.vocab_size - 1).astype(np.int32)
    if cfg.microbatches > 1:
        toks = toks.reshape(cfg.microbatches, cfg.global_batch // cfg.microbatches, cfg.seq_len)
    batch = {"tokens": toks}
    if cfg.frontend:
        fshape = (cfg.global_batch, cfg.frontend_len, cfg.d_model)
        if cfg.microbatches > 1:
            fshape = (cfg.microbatches, cfg.global_batch // cfg.microbatches,
                      cfg.frontend_len, cfg.d_model)
        batch["frontend"] = rng.standard_normal(fshape).astype(np.float32) * 0.02
    return batch


class PrefetchLoader:
    """Background-thread prefetch with backup-batch fallback."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        self.q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch_depth)
        self.stalls = 0
        self._stop = threading.Event()
        self._backup = None
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        step = self.step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.5)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        try:
            step, batch = self.q.get(timeout=self.cfg.timeout_s)
            self._backup = batch
            return batch
        except queue.Empty:
            # Straggler mitigation: don't block the synchronous step — reuse
            # the last good batch and record the stall.
            self.stalls += 1
            if self._backup is None:
                return synth_batch(self.cfg, self.step)
            return self._backup

    def __iter__(self) -> Iterator:
        return self

    def close(self):
        self._stop.set()
