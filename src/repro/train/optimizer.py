"""AdamW from scratch (no optax), with configurable optimizer-state dtype.

State dtype matters at scale: fp32 (m, v) for a 480B-param model is 3.8 TB —
more than a 256-chip v5e pod holds — so arctic-class models run with bf16
state (quantise-on-write, fp32 math). This is one of the "distributed
optimisation tricks" recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"      # "float32" | "bfloat16"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_state(cfg: AdamWConfig, params) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_shapes(cfg: AdamWConfig, params_tree):
    return jax.eval_shape(lambda p: init_state(cfg, p), params_tree)


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    )


def apply_updates(
    cfg: AdamWConfig, params, state, grads
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = _schedule(cfg, step.astype(jnp.float32))
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.beta1, cfg.beta2
    corr1 = 1 - b1 ** step.astype(jnp.float32)
    corr2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32

    def upd(p, m, v, g):
        gf = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / corr1
        vhat = vf / corr2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(sdt), vf.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_g = jax.tree.leaves(grads)
    out = [upd(p, m, v, g) for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
