"""Training step factory: value_and_grad + microbatch accumulation + AdamW.

Distribution is GSPMD: the batch is sharded over (pod, data), params per the
partitioning rules (TP over model, optional FSDP, EP for experts). Gradient
cross-replica reduction is emitted by autodiff inside the per-layer scan, so
layer i's gradient all-reduce overlaps layer i+1's backward compute under
XLA's latency-hiding scheduler (the compute/comm overlap trick — visible in
the dry-run HLO as interleaved all-reduces).

Microbatch count comes from the BFS/DFS-adaptive rule
(core.adaptive_schedule): batches arrive as [n_micro, B_micro, S] and are
scanned, accumulating fp32 gradients.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, apply_updates, init_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    moe_aux_weight: float = 0.01
    compress_pods: bool = False   # int8 error-feedback cross-pod grad exchange


def _loss(cfg_model, params, batch, aux_weight):
    loss = T.loss_fn(cfg_model, params, batch)
    if cfg_model.num_experts and aux_weight:
        # auxiliary router balance loss on the first moe block's router
        from repro.models.moe import router_aux_loss
        dt = T.dtype_of(cfg_model.dtype)
        emb = params["embed"]
        x = jnp.take(emb, jnp.clip(batch["tokens"], 0, cfg_model.vocab_size - 1), axis=0).astype(dt)
        for pos in range(cfg_model.period):
            if cfg_model.mlp_at(pos) in ("moe", "moe_dense"):
                moe_p = jax.tree.map(lambda t: t[0], params["blocks"][pos]["moe"])
                loss = loss + aux_weight * router_aux_loss(
                    moe_p, x, cfg_model.experts_per_token
                )
                break
    return loss


def make_train_step(cfg_model: T.ModelConfig, cfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) → (params, opt_state, metrics).

    batch["tokens"]: [B, S] when microbatches == 1 else [n_micro, B_micro, S].
    """

    def loss_of(params, mb):
        return _loss(cfg_model, params, mb, cfg.moe_aux_weight)

    def train_step(params, opt_state, batch):
        if cfg.microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(acc, mb):
                l, g = jax.value_and_grad(loss_of)(params, mb)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return acc, l

            grads, losses = jax.lax.scan(mb_step, zeros, batch)
            grads = jax.tree.map(lambda g: g / cfg.microbatches, grads)
            loss = jnp.mean(losses)
        if cfg.compress_pods:
            from repro.models.sharding import active_mesh
            from repro.train.compress import compress_gradients
            mesh = active_mesh()
            err = opt_state.get("err") if isinstance(opt_state, dict) else None
            grads, err = compress_gradients(grads, mesh, "pod", err)
        new_params, new_opt, metrics = apply_updates(cfg.adamw, params, opt_state, grads)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def init_all(cfg_model: T.ModelConfig, cfg: TrainConfig, key):
    params = T.init_params(cfg_model, key)
    opt_state = init_state(cfg.adamw, params)
    return params, opt_state
