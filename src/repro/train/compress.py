"""Int8 error-feedback gradient compression for the cross-pod DP exchange.

At 512+ chips the inter-pod (DCI) links are the slowest hop, so the cross-pod
gradient all-reduce dominates the collective roofline term. We compress it:
per-chunk int8 quantisation with error feedback (the quantisation residual is
added back into the next step's gradient, preserving convergence in
expectation). The reduce happens as reduce-scatter(int8) → local fp32 sum →
all-gather(int8): the bytes on the wire drop 2× vs bf16 / 4× vs fp32, and the
reduction math stays fp32.

Implemented as a shard_map over the ``pod`` axis so the int8 collectives are
explicit in the lowered HLO — the roofline harness measures the saving
directly (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(flat: jax.Array, axis: str, mesh) -> jax.Array:
    """Mean-reduce a flat fp32 vector over ``axis`` with int8 wire format.

    flat must be reshapeable to [pods, chunk]: we pad to a multiple of the
    axis size, reduce-scatter in int8, sum locally in fp32, then all-gather
    the re-quantised partial sums.
    """
    n = mesh.shape[axis]

    def f(x):
        size = x.shape[0]
        pad = (-size) % (n * 128)
        xp = jnp.pad(x, (0, pad)).reshape(n, -1, 128)
        q, s = _quant(xp)                                   # int8 + f32 scale/row
        # reduce-scatter: a2a my n chunks, receive n partials of my chunk
        q_r = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0, tiled=True)
        s_r = jax.lax.all_to_all(s, axis, split_axis=0, concat_axis=0, tiled=True)
        part = jnp.sum(_dequant(q_r, s_r).reshape(n, -1, 128), axis=0) / n
        q2, s2 = _quant(part)
        qg = jax.lax.all_gather(q2, axis, axis=0, tiled=False)
        sg = jax.lax.all_gather(s2, axis, axis=0, tiled=False)
        full = _dequant(qg, sg).reshape(-1)[:size + pad]
        return full[:size] if pad == 0 else full[:size]

    return shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)(flat)


def compress_gradients(grads, mesh, axis: str = "pod", error_state=None):
    """Apply compressed cross-pod mean to every gradient leaf, with error
    feedback. Returns (new_grads, new_error_state)."""
    if mesh is None or axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return grads, error_state
    leaves, treedef = jax.tree.flatten(grads)
    err = jax.tree.leaves(error_state) if error_state is not None else [None] * len(leaves)
    new_leaves, new_err = [], []
    for g, ebuf in zip(leaves, err):
        gf = g.astype(jnp.float32)
        if ebuf is not None:
            gf = gf + ebuf
        flat = gf.reshape(-1)
        red = compressed_psum_mean(flat, axis, mesh).reshape(g.shape)
        new_err.append((gf - red).astype(jnp.bfloat16))  # residual feedback
        new_leaves.append(red.astype(g.dtype))
    return jax.tree.unflatten(treedef, new_leaves), jax.tree.unflatten(treedef, new_err)
