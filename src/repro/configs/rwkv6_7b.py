"""rwkv6-7b [ssm] — Finch: 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536. Data-dependent decay linear recurrence (head size 64).
[arXiv:2404.05892; hf]

HUGE applicability: token mixing is attention-free — there is no sparse
dispatch join to configure, so the push/pull-hybrid rule is inapplicable to
the mixer (recorded in DESIGN.md §Arch-applicability); the adaptive
microbatch scheduler still applies.
"""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,            # head size 64
        num_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        layer_pattern=("rwkv",),
        sub_quadratic=True,      # O(1)-state decode → long_500k runs
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, attn_chunk=64,
    )
