"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000. Local+global alternating attention, logit softcap.
[arXiv:2408.00118; hf]"""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        layer_pattern=("attn_local", "attn"),   # alternating 4k-window / global
        local_window=4096,
        attn_softcap=50.0,
        logit_softcap=30.0,
        rope_theta=1e4,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, local_window=16, attn_chunk=64,
    )
