"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155. GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab_size=49155,
        rope_theta=1e4,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, attn_chunk=64,
    )
