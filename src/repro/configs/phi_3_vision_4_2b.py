"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (kv=32, MHA) d_ff=8192
vocab=32064. phi3-mini backbone + CLIP vision frontend. The vision tower is a
STUB: ``input_specs()`` provides 256 precomputed patch embeddings [B, 256,
d_model] prepended to the token sequence.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32064,
        rope_theta=1e4,
        frontend="vision",
        frontend_len=256,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, frontend_len=8, attn_chunk=64,
    )
