"""The paper's own workload configs: distributed subgraph enumeration.

``--arch huge-enum`` selects the paper-native architecture: a partitioned
data graph + query + the HUGE engine. The "shapes" are (graph size × query)
pairs mirroring the paper's (dataset × q_i) grid at CI scale.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class EnumConfig:
    name: str = "huge-enum"
    num_vertices: int = 1 << 14
    avg_degree: float = 8.0
    query: str = "q1"
    batch_size: int = 1024
    queue_capacity: int = 1 << 18
    cache_capacity: int = 1 << 14
    num_machines: int = 8
    seed: int = 7

    def scaled(self, **kw) -> "EnumConfig":
        return dataclasses.replace(self, **kw)


def config() -> EnumConfig:
    return EnumConfig()


def smoke() -> EnumConfig:
    return EnumConfig(num_vertices=256, avg_degree=6.0, batch_size=128,
                      queue_capacity=1 << 14, cache_capacity=1 << 10, num_machines=4)
