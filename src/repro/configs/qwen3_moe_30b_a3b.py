"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8. Every layer is MoE (768-wide experts).
[hf:Qwen/Qwen3-30B-A3B; hf]

This is the most paper-representative LM cell: the expert dispatch is the
hash-join shuffle and HUGE's push/pull-hybrid rule picks the collective.
"""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        mlp_pattern=("moe",),
        num_experts=128,
        experts_per_token=8,
        moe_d_ff=768,
        moe_comm="auto",
        rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=256, num_experts=8, experts_per_token=2,
        moe_d_ff=64, attn_chunk=64,
    )
