"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206. Encoder-decoder, multimodal. The speech frontend is a STUB:
``input_specs()`` feeds precomputed frame embeddings [B, S, d_model] to the
encoder; the decoder is a causal text LM with cross-attention.
[arXiv:2308.11596; hf]"""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        num_layers=24,           # decoder layers
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        rope_theta=1e4,
        frontend="audio",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, encoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, attn_chunk=64,
    )
