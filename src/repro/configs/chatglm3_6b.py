"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024. 2d-RoPE (rotation on half the head dim), GQA.
[arXiv:2406.12793; hf]"""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65024,
        rope_theta=1e4,
        rope_fraction=0.5,   # chatglm rotates only half of each head (2d RoPE)
        qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, attn_chunk=64,
    )
