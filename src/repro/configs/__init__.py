"""Architecture registry (``--arch <id>``) and the assigned input-shape grid.

10 assigned LM architectures × 4 shapes = 40 cells, plus the paper-native
``huge-enum`` workload. ``long_500k`` lowers only for sub-quadratic archs
(rwkv6, jamba) — see DESIGN.md §Arch-applicability for the skip rationale.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

ARCH_MODULES: Dict[str, str] = {
    "granite-3-8b": "repro.configs.granite_3_8b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "command-r-35b": "repro.configs.command_r_35b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "arctic-480b": "repro.configs.arctic_480b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "phi-3-vision-4.2b": "repro.configs.phi_3_vision_4_2b",
    "huge-enum": "repro.configs.huge_enum",
}

ARCH_NAMES = [a for a in ARCH_MODULES if a != "huge-enum"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def get_config(name: str):
    mod = importlib.import_module(ARCH_MODULES[name])
    return mod.config()


def smoke_config(name: str):
    mod = importlib.import_module(ARCH_MODULES[name])
    return mod.smoke()


def shape_skip_reason(arch: str, shape: str) -> Optional[str]:
    """None if the (arch × shape) cell runs; else the documented skip."""
    if shape == "long_500k":
        cfg = get_config(arch)
        if not getattr(cfg, "sub_quadratic", False):
            return "SKIP(full-attn): 500k-context needs sub-quadratic attention"
    return None


def all_cells():
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            yield arch, shape, shape_skip_reason(arch, shape)
