"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2. Mamba:attention 7:1 interleave (one
attention layer per 8-layer block, at position 4), MoE every other layer.
[arXiv:2403.19887; hf]"""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        layer_pattern=(
            "mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba",
        ),
        mlp_pattern=("dense", "moe"),
        num_experts=16,
        experts_per_token=2,
        moe_d_ff=14336,
        moe_comm="auto",
        ssm_state=16,
        ssm_conv=4,
        mamba_expand=2,
        sub_quadratic=True,   # mamba state + 4 attention layers → long_500k runs
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=256, num_experts=4, experts_per_token=2,
        moe_d_ff=64, attn_chunk=64,
    )
