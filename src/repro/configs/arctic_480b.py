"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 **plus a dense residual MLP in parallel** (Snowflake's
dense-MoE hybrid). [hf:Snowflake/snowflake-arctic-base; hf]

Notes: 56 heads shard unevenly over model=16 (GSPMD pads); fp32 Adam states
for 480B cannot fit a 4 TB v5e pod — training uses bf16 optimizer state
(see EXPERIMENTS.md §Perf).
"""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        mlp_pattern=("moe_dense",),   # dense residual in parallel with MoE
        num_experts=128,
        experts_per_token=2,
        moe_d_ff=4864,
        moe_comm="auto",
        rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=256, num_experts=8, experts_per_token=2,
        moe_d_ff=64, attn_chunk=64,
    )
