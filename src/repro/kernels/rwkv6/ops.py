"""Public RWKV6 entry: Pallas kernel on TPU, chunked jnp scan elsewhere, plus
the O(1)-state single-step used by the decode path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.ref import rwkv6_ref
from repro.kernels.rwkv6.rwkv6 import rwkv6_kernel, DEFAULT_CHUNK


@functools.partial(jax.jit, static_argnames=("chunk", "return_state", "stable_factored"))
def rwkv6_chunked(r, k, v, w, u, *, chunk: int = 32, return_state: bool = False,
                  stable_factored: bool = True):
    """Chunked scan in portable jnp, vectorised over BH, scanned over chunks.

    stable_factored=True (default, and what the dry-run lowers): the intra-
    chunk pair interaction is a **normalised factored matmul**,

        A[t,j] = (r_t ⊙ e^{c_{t-1} − z}) · (k_j ⊙ e^{z − c_j}),   z = c_C / 2,

    which is exact for any per-channel normaliser z and turns the O(C²K)
    pairwise tensor (≈1 PB/step of HBM traffic at the train_4k cell — see
    EXPERIMENTS.md §Perf iteration 1) into two O(CK) operands and one MXU
    matmul. fp32 range bounds the usable per-step log-decay at |log w| ≤ ~3.3
    with C=32 (|c|/2 ≤ 53 < log(f32max)=88) — the model clamps accordingly
    (ssm.rwkv6_block). stable_factored=False keeps the exact-for-any-decay
    pairwise path (tests compare both against the sequential oracle).
    """
    bh, t, kd = r.shape
    vd = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    n = t // chunk
    f32 = jnp.float32
    # (A bf16-xs variant was tried and REFUTED in §Perf rwkv6 iteration 2 —
    # the xs streams are not the dominant traffic — so everything stays f32.)

    def resh(x, d):
        return x.astype(f32).reshape(bh, n, chunk, d).transpose(1, 0, 2, 3)

    rc, kc, wc = resh(r, kd), resh(k, kd), resh(w, kd)
    vc = resh(v, vd)
    uf = u.astype(f32)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def step(S, xs):
        rb, kb, vb, wb = xs                                # [BH, C, ·]
        logw = jnp.log(jnp.maximum(wb, 1e-12))
        cum = jnp.cumsum(logw, axis=1)
        cum_prev = cum - logw
        if stable_factored:
            z = cum[:, -1:, :] * 0.5                       # per-channel centre
            r_z = rb * jnp.exp(cum_prev - z)               # [BH, C, K]
            k_z = kb * jnp.exp(z - cum)
            a = jnp.einsum("bti,bji->btj", r_z, k_z)       # MXU matmul
            a = jnp.where(tri[None], a, 0.0)
        else:
            diff = cum_prev[:, :, None, :] - cum[:, None, :, :]  # [BH, C, C, K]
            decay = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
            a = jnp.einsum("bti,bji,btji->btj", rb, kb, decay)
        a = a + jnp.einsum("bti,bi,bti->bt", rb, uf, kb)[..., None] * jnp.eye(chunk)[None]
        out = jnp.einsum("bti,biv->btv", rb * jnp.exp(cum_prev), S) + jnp.einsum(
            "btj,bjv->btv", a, vb
        )
        k_dec = kb * jnp.exp(cum[:, -1:, :] - cum)
        S = jnp.exp(cum[:, -1])[:, :, None] * S + jnp.einsum("bji,bjv->biv", k_dec, vb)
        return S, out

    S0 = jnp.zeros((bh, kd, vd), f32)
    s_fin, out = jax.lax.scan(step, S0, (rc, kc, vc, wc))
    out = out.transpose(1, 0, 2, 3).reshape(bh, t, vd)
    return (out, s_fin) if return_state else out


@jax.jit
def rwkv6_decode_step(S, r, k, v, w, u):
    """One token with carried state S[BH, K, V] → (S', out[BH, V])."""
    f32 = jnp.float32
    r, k, v, w, u = (x.astype(f32) for x in (r, k, v, w, u))
    kv = k[:, :, None] * v[:, None, :]
    out = jnp.einsum("bi,biv->bv", r, S + u[:, :, None] * kv)
    S = w[:, :, None] * S + kv
    return S, out


def rwkv6(r, k, v, w, u, *, force_kernel: bool = False, chunk: int = 64):
    if jax.default_backend() == "tpu":
        return rwkv6_kernel(r, k, v, w, u, chunk=DEFAULT_CHUNK)
    if force_kernel:
        return rwkv6_kernel(r, k, v, w, u, chunk=min(DEFAULT_CHUNK, r.shape[1]), interpret=True)
    return rwkv6_chunked(r, k, v, w, u, chunk=chunk)
