"""Pallas TPU kernel: chunked RWKV6 scan with data-dependent decay.

TPU adaptation: the sequential recurrence is blocked into chunks of C steps.
Within a chunk the contribution of every pair (t, j ≤ t) is computed in
*log-decay space* — exponent differences are taken **pairwise**
(``exp(cum[t-1] - cum[j])``), never as a factored matmul, so no channel ever
exponentiates an unbounded cumulative decay (the classic overflow of
linear-attention chunking). Cross-chunk state S [K, V] is carried in VMEM
scratch across the sequential chunk grid axis.

Per chunk (local cum-log-decay ``c_t = Σ_{s≤t} log w_s``, ``c_0 = 0``):
    A[t, j] = Σ_i r[t,i]·k[j,i]·exp(c_{t-1,i} − c_{j,i})     (j <  t)
    A[t, t] = Σ_i r[t,i]·u_i·k[t,i]
    out     = (r ⊙ exp(c_{t-1})) @ S_in  +  A @ v
    S_out   = diag(exp(c_C)) S_in + Σ_j (k_j ⊙ exp(c_C − c_j)) v_jᵀ

VMEM per program: chunk tiles C·K·4 × 4 + pairwise tensor C²·K·4
(C=32, K=64 → ≈ 0.3 MiB) + state K·V·4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 32


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)        # [C, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)        # [C, V]
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)        # [1, K] block

    logw = jnp.log(jnp.maximum(w, 1e-12))
    cum = jnp.cumsum(logw, axis=0)          # c_t, t = 1..C  → cum[t-1] row
    cum_prev = cum - logw                   # c_{t-1}

    # Pairwise decay exponents: exp(c_{t-1,i} - c_{j,i}) for j ≤ t-1.
    diff = cum_prev[:, None, :] - cum[None, :, :]          # [C, C, K]
    c = r.shape[0]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
        > jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    )  # strict lower triangle: j < t
    decay = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    a = jnp.sum(r[:, None, :] * k[None, :, :] * decay, axis=2)   # [C, C]
    a = a + jnp.diag(jnp.sum(r * u * k, axis=1))

    s_in = state_ref[...]                                   # [K, V]
    r_dec = r * jnp.exp(cum_prev)
    out = jax.lax.dot_general(
        r_dec, s_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)

    k_dec = k * jnp.exp(cum[-1:, :] - cum)                  # k_j ⊙ exp(c_C - c_j)
    state_ref[...] = jnp.exp(cum[-1])[:, None] * s_in + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_kernel(r, k, v, w, u, *, chunk: int = DEFAULT_CHUNK, interpret: bool = False):
    bh, t, kd = r.shape
    vd = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    grid = (bh, t // chunk)
    u3 = u[:, None, :]  # [BH, 1, K]
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, kd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, kd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, vd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, kd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, kd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, vd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, vd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((kd, vd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u3)
