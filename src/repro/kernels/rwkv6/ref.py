"""Pure-jnp oracle for RWKV6 (Finch) — sequential state recurrence.

Per head with key dim K, value dim V, at each step t:

    out_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ

with data-dependent decay w_t ∈ (0, 1) (the paper's headline change over
RWKV5) and per-head bonus u. Shapes: r/k/w [BH, T, K], v [BH, T, V],
u [BH, K] → out [BH, T, V].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, w, u):
    bh, t, kd = r.shape
    vd = v.shape[-1]

    def head(r1, k1, v1, w1, u1):
        def step(S, xs):
            rt, kt, vt, wt = xs
            kv = kt[:, None] * vt[None, :]                 # [K, V]
            out = (rt[:, None] * (S + u1[:, None] * kv)).sum(0)
            S = wt[:, None] * S + kv
            return S, out

        S0 = jnp.zeros((kd, vd), jnp.float32)
        _, out = jax.lax.scan(step, S0, (r1, k1, v1, w1))
        return out

    return jax.vmap(head)(
        r.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), w.astype(jnp.float32), u.astype(jnp.float32),
    )
