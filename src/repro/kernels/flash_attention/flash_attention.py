"""Pallas TPU kernel: blockwise online-softmax (flash) causal attention.

The prefill/train hot spot of every assigned LM cell. Q is tiled over the
grid; KV blocks stream through the innermost grid axis with running
(max, sum, acc) scratch carried across iterations — the canonical TPU
flash-attention schedule. Causality is enforced at block granularity (blocks
entirely above the diagonal are skipped via masking; the diagonal block is
element-masked).

Layout (one head per grid row — callers flatten (batch, heads)):
  q   f32/bf16 [BH, Sq, Dh]
  k,v f32/bf16 [BH, Sk, Dh]
  out f32      [BH, Sq, Dh]

BlockSpecs: q tile (1, TQ, Dh), kv tiles (1, TK, Dh); scratch in VMEM:
acc (TQ, Dh) f32, m/l (TQ, 128) f32. With TQ=TK=512, Dh=128 the VMEM
working set is ≈ 0.8 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TQ = 256
DEFAULT_TK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, tq: int, tk: int, causal: bool, kv_steps: int,
            softcap: float | None, offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                      # [TQ, Dh]
    k = k_ref[0].astype(jnp.float32)                      # [TK, Dh]
    v = v_ref[0].astype(jnp.float32)                      # [TK, Dh]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # [TQ, TK]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    mask = None
    if causal:
        # offset = Sk - Sq aligns the diagonal when the query is a suffix of
        # the key sequence (decode with a prefix KV cache).
        q_pos = qi * tq + offset + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                                 # [TQ, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)             # [TQ, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                # [TQ, TK]
    if mask is not None:
        # A fully-masked block with m still at NEG_INF would give p = 1.
        p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                       # [TQ, 1]
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == kv_steps - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "tq", "tk", "interpret", "softcap"),
)
def flash_attention_kernel(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    softcap: float | None = None,
    tq: int = DEFAULT_TQ,
    tk: int = DEFAULT_TK,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, dh = q.shape
    _, sk, _ = k.shape
    tq = min(tq, sq)
    tk = min(tk, sk)
    assert sq % tq == 0 and sk % tk == 0, (sq, tq, sk, tk)
    kv_steps = sk // tk
    scale = 1.0 / (dh ** 0.5)
    grid = (bh, sq // tq, kv_steps)
    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, tq=tq, tk=tk, causal=causal,
            kv_steps=kv_steps, softcap=softcap, offset=sk - sq,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, dh), jnp.float32),   # acc
            pltpu.VMEM((tq, 128), jnp.float32),  # running max (lane-padded)
            pltpu.VMEM((tq, 128), jnp.float32),  # running sum
        ],
        interpret=interpret,
    )(q, k, v)
