"""Pure-jnp oracle for flash attention: materialised-scores softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # [BH, Sq, Dh]
    k: jax.Array,  # [BH, Sk, Dh]
    v: jax.Array,  # [BH, Sk, Dh]
    *,
    causal: bool = True,
    softcap: float | None = None,
) -> jax.Array:
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (dh ** 0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
