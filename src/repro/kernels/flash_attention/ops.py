"""Public attention entry point: Pallas kernel on TPU, chunked-scan jnp
implementation elsewhere (identical O(S·TK) memory, compilable for the
dry-run), oracle for tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "softcap", "chunk"))
def attention_chunked(
    q: jax.Array,  # [BH, Sq, Dh]
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    softcap: float | None = None,
    chunk: int = 512,
) -> jax.Array:
    """Online-softmax attention with a lax.scan over KV chunks — the same
    algorithm as the Pallas kernel expressed in portable jnp. Peak memory is
    O(Sq·chunk) instead of O(Sq·Sk); this is what the dry-run lowers."""
    bh, sq, dh = q.shape
    sk = k.shape[1]
    chunk = min(chunk, sk)
    if sk % chunk:  # pad KV to a chunk multiple (masked out)
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    n_chunks = k.shape[1] // chunk
    qf = q.astype(jnp.float32) / (dh ** 0.5)
    kc = k.reshape(bh, n_chunks, chunk, dh).transpose(1, 0, 2, 3)
    vc = v.reshape(bh, n_chunks, chunk, dh).transpose(1, 0, 2, 3)

    q_pos = jnp.arange(sq)

    def step(carry, xs):
        acc, m, l = carry
        ci, kb, vb = xs
        s = jnp.einsum("bqd,bkd->bqk", qf, kb.astype(jnp.float32))
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] < sk  # padding
        if causal:
            mask = mask & (q_pos[:, None] + (sk - sq) >= k_pos[None, :])
        s = jnp.where(mask[None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask[None], jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bqk,bkd->bqd", p, vb.astype(jnp.float32))
        return (acc, m_new, l), None

    init = (
        jnp.zeros((bh, sq, dh), jnp.float32),
        jnp.full((bh, sq, 1), -1e30, jnp.float32),
        jnp.zeros((bh, sq, 1), jnp.float32),
    )
    (acc, m, l), _ = jax.lax.scan(step, init, (jnp.arange(n_chunks), kc, vc))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, softcap: float | None = None,
    force_kernel: bool = False, chunk: int = 512,
) -> jax.Array:
    if jax.default_backend() == "tpu":
        return flash_attention_kernel(q, k, v, causal=causal, softcap=softcap)
    if force_kernel:
        return flash_attention_kernel(q, k, v, causal=causal, softcap=softcap, interpret=True)
    return attention_chunked(q, k, v, causal=causal, softcap=softcap, chunk=chunk)
