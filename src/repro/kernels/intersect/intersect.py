"""Pallas TPU kernels for the enumeration hot path (paper Eq. 2, Alg. 3-4).

Three kernels implement the probe-fetch-intersect contract of DESIGN.md
§Fused-hot-path (the fused twin of the plain-jnp path in core/operators.py):

``multiway_membership_kernel``
    The bare Eq.-2 membership: for every partial match, test each candidate
    neighbour of the pivot against the (sorted, INVALID-padded) adjacency rows
    of all other extension vertices. The CPU implementation binary-searches;
    on TPU dynamic per-lane gathers are hostile to the VPU, so we *adapt*
    (per the brief, not port): membership is a **tiled compare-any** — the
    candidate lane vector is compared against sublane-broadcast chunks of the
    other rows, reducing with ``|``. Work is O(D²/chunk) compares per row
    instead of O(D log D) scalar searches, but runs at full lane width; for
    the D ≤ 2k adjacency rows HUGE sees, compare-any wins on TPU.

``fused_extend_kernel`` / ``fused_verify_kernel``
    The full extend/verify hot path in one pass: per (row, extension-vertex)
    pair, gather the adjacency slab from one of *two* source tables — the
    LRBU value cache (single-device engine) or the fetched remote table
    (distributed engine) vs the local adjacency — select by the probe's
    hit mask, then run the Eq.-2 intersection plus injectivity and
    symmetry-break filters without materialising ``[B, E, D]`` slabs in HBM
    between stages. The gather is expressed through
    ``PrefetchScalarGridSpec``: slab row indices are scalar-prefetched and
    drive the BlockSpec index maps, so Pallas streams exactly the addressed
    slabs through VMEM (double-buffered); the probe's address computation is
    a tiny [B, E] scalar prologue that stays in jnp (see ops.py).

``lex_bounds_kernel``
    The PUSH-JOIN probe: equal-range bounds of each right-batch key in the
    sorted left side buffer. Binary search is again gather-hostile, so the
    bounds are computed as **tiled compare-count**: stream the sorted keys
    chunk-wise and count ``keys <lex q`` and ``keys ==lex q`` per query —
    ``lo = Σ lt``, ``cnt = Σ eq`` — which for a sorted table equals
    (searchsorted-left, equal-run length). O(CAP·B/lane) dense compares,
    zero gathers, accumulated across a 2-D grid.

All kernels run under ``interpret=True`` off-TPU so CPU CI executes the
kernel semantics (grid is scanned, not unrolled); pure-jnp reference twins
live in ref.py and dispatch in ops.py.

Layout of the bare membership kernel:
  cands  int32[B, D]      candidate vertices (pivot's adjacency rows)
  others int32[B, E, D]   adjacency rows of the other E extension vertices
  out    bool [B, D]      candidate present in *all* E rows

Grid: one program per TILE_B rows; E and the chunk loop are unrolled inside
(E ≤ 4 for real queries). BlockSpecs keep (TILE_B, D) tiles in VMEM: with
TILE_B=8, D=2048, E=3 the working set is 8·2048·(1+3)·4 B ≈ 256 KiB ≪ 16 MiB.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.graph.storage import INVALID

TILE_B = 8
CHUNK = 128  # lanes compared per step


def _kernel(cands_ref, others_ref, out_ref, *, n_other: int, d: int, chunk: int):
    cands = cands_ref[...]                      # [TILE_B, D]
    acc = jnp.ones(cands.shape, dtype=jnp.bool_)
    for e in range(n_other):
        row = others_ref[:, e, :]               # [TILE_B, D]
        member = jnp.zeros(cands.shape, dtype=jnp.bool_)
        for c0 in range(0, d, chunk):
            blk = row[:, c0 : c0 + chunk]       # [TILE_B, CHUNK]
            # candidate lanes vs broadcast chunk: [TILE_B, D, CHUNK] compare.
            eq = cands[:, :, None] == blk[:, None, :]
            member = member | jnp.any(eq, axis=2)
        acc = acc & member
    out_ref[...] = acc & (cands != INVALID)


@functools.partial(jax.jit, static_argnames=("interpret",))
def multiway_membership_kernel(cands: jax.Array, others: jax.Array, *, interpret: bool = False) -> jax.Array:
    """cands[B, D] ∈ all of others[B, E, D]? (rows need not be sorted)."""
    b, d = cands.shape
    _, e, _ = others.shape
    assert b % TILE_B == 0, f"batch {b} must be a multiple of {TILE_B}"
    grid = (b // TILE_B,)
    return pl.pallas_call(
        functools.partial(_kernel, n_other=e, d=d, chunk=min(CHUNK, d)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B, e, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.bool_),
        interpret=interpret,
    )(cands, others)


# ---------------------------------------------------------------------------
# Fused extend/verify: probe-select slab gather → Eq.-2 intersection → filters
# ---------------------------------------------------------------------------
#
# Slab addressing contract (shared with ref.py / ops.py):
#   slab[b, e] = tab0[idx[0, b, e]]  if sel[b, e]
#              = tab1[idx[1, b, e]]  otherwise,
#   masked to INVALID where ~ok[b, e].
# ``tab0`` is the probe's primary source (LRBU value-cache slabs or the
# fetched remote table), ``tab1`` the fallback (local padded adjacency);
# both hold sorted, INVALID-padded rows of equal width D. Indices must be
# pre-clipped to the tables' row counts.


def _member_any(cands: jax.Array, row: jax.Array, d: int, chunk: int) -> jax.Array:
    """Compare-any membership of cands[T, D] in row[T, D] (chunked lanes)."""
    member = jnp.zeros(cands.shape, dtype=jnp.bool_)
    for c0 in range(0, d, chunk):
        blk = row[:, c0 : c0 + chunk]
        member = member | jnp.any(cands[:, :, None] == blk[:, None, :], axis=2)
    return member


def _fused_extend_kernel_body(
    sidx_ref, *refs, n_ext: int, k: int, d: int, chunk: int,
    lt: Tuple[int, ...], gt: Tuple[int, ...],
):
    del sidx_ref  # consumed by the BlockSpec index maps
    t0 = refs[:n_ext]
    t1 = refs[n_ext : 2 * n_ext]
    sel_ref, ok_ref, rows_ref = refs[2 * n_ext : 2 * n_ext + 3]
    cands_ref, mask_ref = refs[2 * n_ext + 3 :]

    def slab(e: int) -> jax.Array:
        s = jnp.where(sel_ref[0, e] == 1, t0[e][...], t1[e][...])  # [1, D]
        return jnp.where(ok_ref[0, e] == 1, s, INVALID)

    cands = slab(0)
    acc = cands != INVALID
    for e in range(1, n_ext):
        acc = acc & _member_any(cands, slab(e), d, chunk)
    rows = rows_ref[...]  # [1, K]
    # Isomorphism (injectivity) check — Alg. 4 line 19.
    for col in range(k):
        acc = acc & (cands != rows[:, col : col + 1])
    # Symmetry-breaking partial orders.
    for p in lt:
        acc = acc & (cands < rows[:, p : p + 1])
    for p in gt:
        acc = acc & (cands > rows[:, p : p + 1])
    cands_ref[...] = cands
    mask_ref[...] = acc


def _slab_grid_spec(b: int, d: int, e: int, k: int) -> pltpu.PrefetchScalarGridSpec:
    """Grid over rows; slab BlockSpecs gather via the prefetched idx[2, B, E]."""

    def tab_spec(which: int, col: int) -> pl.BlockSpec:
        return pl.BlockSpec((1, d), lambda i, s, w=which, c=col: (s[w, i, c], 0))

    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            *(tab_spec(0, c) for c in range(e)),
            *(tab_spec(1, c) for c in range(e)),
            pl.BlockSpec((1, e), lambda i, s: (i, 0)),  # sel
            pl.BlockSpec((1, e), lambda i, s: (i, 0)),  # ok
            pl.BlockSpec((1, k), lambda i, s: (i, 0)),  # rows
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i, s: (i, 0)),
            pl.BlockSpec((1, d), lambda i, s: (i, 0)),
        ],
    )


@functools.partial(jax.jit, static_argnames=("lt", "gt", "interpret"))
def fused_extend_kernel(
    tab0: jax.Array,   # int32[R0, D] sorted INVALID-padded slabs (probe source)
    tab1: jax.Array,   # int32[R1, D] fallback slabs (local adjacency)
    idx: jax.Array,    # int32[2, B, E] pre-clipped row indices into tab0/tab1
    sel: jax.Array,    # int32[B, E] 1 → tab0, 0 → tab1
    ok: jax.Array,     # int32[B, E] 0 → slab forced to INVALID
    rows: jax.Array,   # int32[B, K] partial matches
    *,
    lt: Tuple[int, ...] = (),
    gt: Tuple[int, ...] = (),
    interpret: bool = False,
):
    """Fused PULL-EXTEND hot path. Returns (cands[B, D], mask[B, D]).

    ``cands`` is slab 0 (the pivot's adjacency); ``mask`` marks candidates
    present in every other slab that also pass injectivity and lt/gt orders.
    Row validity is NOT applied here — callers AND the batch's valid mask in.
    """
    b, k = rows.shape
    e = idx.shape[2]
    d = tab0.shape[1]
    assert tab1.shape[1] == d, (tab0.shape, tab1.shape)
    kernel = functools.partial(
        _fused_extend_kernel_body,
        n_ext=e, k=k, d=d, chunk=min(CHUNK, d), lt=lt, gt=gt,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=_slab_grid_spec(b, d, e, k),
        out_shape=[
            jax.ShapeDtypeStruct((b, d), jnp.int32),
            jax.ShapeDtypeStruct((b, d), jnp.bool_),
        ],
        interpret=interpret,
    )(idx, *([tab0] * e), *([tab1] * e), sel, ok, rows)


def _fused_verify_kernel_body(
    sidx_ref, *refs, n_ext: int, k: int, d: int, chunk: int, vpos: int,
):
    del sidx_ref
    t0 = refs[:n_ext]
    t1 = refs[n_ext : 2 * n_ext]
    sel_ref, ok_ref, rows_ref = refs[2 * n_ext : 2 * n_ext + 3]
    (mask_ref,) = refs[2 * n_ext + 3 :]
    target = rows_ref[0, vpos]
    acc = target != INVALID
    for e in range(n_ext):
        s = jnp.where(sel_ref[0, e] == 1, t0[e][...], t1[e][...])
        s = jnp.where(ok_ref[0, e] == 1, s, INVALID)
        member = jnp.zeros((), jnp.bool_)
        for c0 in range(0, d, chunk):
            member = member | jnp.any(s[:, c0 : c0 + chunk] == target)
        acc = acc & member
    mask_ref[0, 0] = acc


@functools.partial(jax.jit, static_argnames=("vpos", "interpret"))
def fused_verify_kernel(
    tab0: jax.Array,
    tab1: jax.Array,
    idx: jax.Array,
    sel: jax.Array,
    ok: jax.Array,
    rows: jax.Array,
    *,
    vpos: int,
    interpret: bool = False,
) -> jax.Array:
    """Fused VERIFY (§5.2 pulling-hash hint): keep rows whose ``rows[:, vpos]``
    is a member of every gathered slab. Returns bool[B] (row validity NOT
    applied — callers AND it in, same contract as fused_extend_kernel)."""
    b, k = rows.shape
    e = idx.shape[2]
    d = tab0.shape[1]
    grid_spec = _slab_grid_spec(b, d, e, k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=grid_spec.in_specs,
        out_specs=[pl.BlockSpec((1, 1), lambda i, s: (i, 0))],
    )
    kernel = functools.partial(
        _fused_verify_kernel_body,
        n_ext=e, k=k, d=d, chunk=min(CHUNK, d), vpos=vpos,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, 1), jnp.bool_)],
        interpret=interpret,
    )(idx, *([tab0] * e), *([tab1] * e), sel, ok, rows)
    return out[0][:, 0]


# ---------------------------------------------------------------------------
# PUSH-JOIN probe: equal-range bounds by tiled compare-count
# ---------------------------------------------------------------------------

BOUNDS_CHUNK = 128  # sorted-key rows per grid step


def _lex_bounds_kernel_body(keys_ref, q_ref, out_ref, *, kk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # [C, KK]
    q = q_ref[...]        # [T, KK]
    lt = jnp.zeros((q.shape[0], keys.shape[0]), jnp.bool_)
    eq = jnp.ones((q.shape[0], keys.shape[0]), jnp.bool_)
    for c in range(kk):
        a = keys[:, c][None, :]
        b = q[:, c][:, None]
        lt = lt | (eq & (a < b))
        eq = eq & (a == b)
    out_ref[:, 0] += jnp.sum(lt, axis=1, dtype=jnp.int32)
    out_ref[:, 1] += jnp.sum(eq, axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lex_bounds_kernel(
    sorted_keys: jax.Array,  # int32[CAP, KK] lexicographically sorted, INVALID-padded
    queries: jax.Array,      # int32[B, KK]
    *,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Equal-range (lo, hi) of each query key in the sorted key table.

    For a sorted table, ``#(keys <lex q)`` is searchsorted-left and
    ``#(keys ==lex q)`` the run length, so the bounds come out of dense
    compare-count accumulation over a (query-tile × key-chunk) grid — no
    per-lane gathers. Queries equal to INVALID rows would miscount, so
    callers encode invalid queries as INVALID-1 (operators.join_probe does).
    """
    cap, kk = sorted_keys.shape
    b = queries.shape[0]
    pad_cap = (-cap) % BOUNDS_CHUNK
    if pad_cap:
        sorted_keys = jnp.concatenate(
            [sorted_keys, jnp.full((pad_cap, kk), INVALID, jnp.int32)], axis=0
        )
    pad_b = (-b) % TILE_B
    if pad_b:
        queries = jnp.concatenate(
            [queries, jnp.full((pad_b, kk), INVALID, jnp.int32)], axis=0
        )
    bp = b + pad_b
    out = pl.pallas_call(
        functools.partial(_lex_bounds_kernel_body, kk=kk),
        grid=((bp // TILE_B), (cap + pad_cap) // BOUNDS_CHUNK),
        in_specs=[
            pl.BlockSpec((BOUNDS_CHUNK, kk), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_B, kk), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_B, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 2), jnp.int32),
        interpret=interpret,
    )(sorted_keys, queries)
    lo = out[:b, 0]
    return lo, lo + out[:b, 1]
