"""Pallas TPU kernel: batched multiway adjacency intersection (paper Eq. 2).

This is HUGE's compute hot spot: for every partial match, test each candidate
neighbour of the pivot against the (sorted, INVALID-padded) adjacency rows of
all other extension vertices. The CPU implementation binary-searches; on TPU
dynamic per-lane gathers are hostile to the VPU, so we *adapt* (per the brief,
not port): membership is computed as a **tiled compare-any** — the candidate
lane vector is compared against sublane-broadcast chunks of the other rows,
reducing with ``|``. This turns Eq. 2 into dense 8x128-lane compares with zero
gathers, which is exactly what the VPU is built for. Work is O(D²/chunk)
compares per row instead of O(D log D) scalar searches, but runs at full lane
width; for the D ≤ 2k adjacency rows HUGE sees, compare-any wins on TPU.

Layout:
  cands  int32[B, D]      candidate vertices (pivot's adjacency rows)
  others int32[B, E, D]   adjacency rows of the other E extension vertices
  out    bool [B, D]      candidate present in *all* E rows

Grid: one program per TILE_B rows; E and the chunk loop are unrolled inside
(E ≤ 4 for real queries). BlockSpecs keep (TILE_B, D) tiles in VMEM: with
TILE_B=8, D=2048, E=3 the working set is 8·2048·(1+3)·4 B ≈ 256 KiB ≪ 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graph.storage import INVALID

TILE_B = 8
CHUNK = 128  # lanes compared per step


def _kernel(cands_ref, others_ref, out_ref, *, n_other: int, d: int, chunk: int):
    cands = cands_ref[...]                      # [TILE_B, D]
    acc = jnp.ones(cands.shape, dtype=jnp.bool_)
    for e in range(n_other):
        row = others_ref[:, e, :]               # [TILE_B, D]
        member = jnp.zeros(cands.shape, dtype=jnp.bool_)
        for c0 in range(0, d, chunk):
            blk = row[:, c0 : c0 + chunk]       # [TILE_B, CHUNK]
            # candidate lanes vs broadcast chunk: [TILE_B, D, CHUNK] compare.
            eq = cands[:, :, None] == blk[:, None, :]
            member = member | jnp.any(eq, axis=2)
        acc = acc & member
    out_ref[...] = acc & (cands != INVALID)


@functools.partial(jax.jit, static_argnames=("interpret",))
def multiway_membership_kernel(cands: jax.Array, others: jax.Array, *, interpret: bool = False) -> jax.Array:
    """cands[B, D] ∈ all of others[B, E, D]? (rows need not be sorted)."""
    b, d = cands.shape
    _, e, _ = others.shape
    assert b % TILE_B == 0, f"batch {b} must be a multiple of {TILE_B}"
    grid = (b // TILE_B,)
    return pl.pallas_call(
        functools.partial(_kernel, n_other=e, d=d, chunk=min(CHUNK, d)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B, e, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.bool_),
        interpret=interpret,
    )(cands, others)
