"""Pure-jnp oracles for the intersect kernels (binary-search membership,
slab-gathered fused extend/verify, lexicographic equal-range bounds)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.graph.storage import INVALID


def multiway_membership_ref(cands: jax.Array, others: jax.Array) -> jax.Array:
    """cands[B, D] present in every others[B, e, :]. ``others`` rows must be
    sorted ascending (INVALID-padded) — the engine's adjacency invariant."""
    b, d = cands.shape
    _, e, _ = others.shape
    acc = cands != INVALID
    for i in range(e):
        row = others[:, i, :]
        idx = jax.vmap(jnp.searchsorted)(row, cands)
        idx = jnp.clip(idx, 0, d - 1)
        found = jnp.take_along_axis(row, idx, axis=-1)
        acc = acc & (found == cands)
    return acc


def gather_slabs(
    tab0: jax.Array, tab1: jax.Array, idx: jax.Array, sel: jax.Array, ok: jax.Array
) -> jax.Array:
    """Materialise the [B, E, D] slab tensor of the fused-kernel contract:
    slab[b, e] = (tab0 if sel else tab1)[idx[·, b, e]], INVALID where ~ok."""
    s0 = jnp.take(tab0, idx[0], axis=0)  # [B, E, D]
    s1 = jnp.take(tab1, idx[1], axis=0)
    slabs = jnp.where((sel == 1)[:, :, None], s0, s1)
    return jnp.where((ok == 1)[:, :, None], slabs, INVALID)


def fused_extend_ref(
    tab0: jax.Array,
    tab1: jax.Array,
    idx: jax.Array,
    sel: jax.Array,
    ok: jax.Array,
    rows: jax.Array,
    *,
    lt: Tuple[int, ...] = (),
    gt: Tuple[int, ...] = (),
) -> Tuple[jax.Array, jax.Array]:
    """Reference twin of fused_extend_kernel: returns (cands[B, D], mask[B, D])."""
    slabs = gather_slabs(tab0, tab1, idx, sel, ok)
    cands = slabs[:, 0, :]
    mask = multiway_membership_ref(cands, slabs[:, 1:, :]) if slabs.shape[1] > 1 \
        else (cands != INVALID)
    k = rows.shape[1]
    for col in range(k):
        mask = mask & (cands != rows[:, col : col + 1])
    for p in lt:
        mask = mask & (cands < rows[:, p : p + 1])
    for p in gt:
        mask = mask & (cands > rows[:, p : p + 1])
    return cands, mask


def fused_verify_ref(
    tab0: jax.Array,
    tab1: jax.Array,
    idx: jax.Array,
    sel: jax.Array,
    ok: jax.Array,
    rows: jax.Array,
    *,
    vpos: int,
) -> jax.Array:
    """Reference twin of fused_verify_kernel: bool[B], rows[:, vpos] present in
    every gathered slab."""
    slabs = gather_slabs(tab0, tab1, idx, sel, ok)
    target = rows[:, vpos]
    acc = target != INVALID
    for e in range(slabs.shape[1]):
        acc = acc & jnp.any(slabs[:, e, :] == target[:, None], axis=1)
    return acc


def _lex_cmp(lrows: jax.Array, r: jax.Array):
    """Lexicographic comparison: returns (lt, eq) of lrows[i] vs r[i]."""
    neq = lrows != r
    first = jnp.argmax(neq, axis=-1)
    any_neq = jnp.any(neq, axis=-1)
    val_l = jnp.take_along_axis(lrows, first[..., None], axis=-1)[..., 0]
    val_r = jnp.take_along_axis(r, first[..., None], axis=-1)[..., 0]
    lt = any_neq & (val_l < val_r)
    return lt, ~any_neq


def lex_bounds_ref(sorted_keys: jax.Array, queries: jax.Array):
    """Vectorised lower/upper bounds of each query key in the sorted key table
    (binary search — the pure-jnp twin of lex_bounds_kernel)."""
    cap = sorted_keys.shape[0]
    bq = queries.shape[0]
    iters = max(1, cap.bit_length())

    def search(upper: bool):
        lo = jnp.zeros((bq,), jnp.int32)
        hi = jnp.full((bq,), cap, jnp.int32)

        def body(_, carry):
            lo, hi = carry
            mid = (lo + hi) // 2
            lrows = jnp.take(sorted_keys, jnp.clip(mid, 0, cap - 1), axis=0)
            lt, eq = _lex_cmp(lrows, queries)
            go_right = (lt | eq) if upper else lt
            lo = jnp.where(go_right, mid + 1, lo)
            hi = jnp.where(go_right, hi, mid)
            return lo, hi

        lo, hi = lax.fori_loop(0, iters, body, (lo, hi))
        return lo

    return search(False), search(True)
