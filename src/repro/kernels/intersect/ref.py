"""Pure-jnp oracle for the intersect kernel (binary-search membership)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph.storage import INVALID


def multiway_membership_ref(cands: jax.Array, others: jax.Array) -> jax.Array:
    """cands[B, D] present in every others[B, e, :]. ``others`` rows must be
    sorted ascending (INVALID-padded) — the engine's adjacency invariant."""
    b, d = cands.shape
    _, e, _ = others.shape
    acc = cands != INVALID
    for i in range(e):
        row = others[:, i, :]
        idx = jax.vmap(jnp.searchsorted)(row, cands)
        idx = jnp.clip(idx, 0, d - 1)
        found = jnp.take_along_axis(row, idx, axis=-1)
        acc = acc & (found == cands)
    return acc
