"""Jitted public wrapper for the intersect kernel with CPU fallback.

The Pallas TPU kernel only lowers on TPU backends; everywhere else (this CI
box) we execute either the pure-jnp oracle (fast XLA path) or the kernel in
``interpret=True`` mode (tests do the latter to validate kernel semantics).
"""
from __future__ import annotations

import jax

from repro.kernels.intersect.intersect import multiway_membership_kernel, TILE_B
from repro.kernels.intersect.ref import multiway_membership_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def multiway_membership(cands: jax.Array, others: jax.Array, *, force_kernel: bool = False) -> jax.Array:
    """Batched Eq.-2 membership: cands[B, D] ∈ ∩ others[B, E, D]."""
    b = cands.shape[0]
    if (_on_tpu() and b % TILE_B == 0):
        return multiway_membership_kernel(cands, others)
    if force_kernel:
        return multiway_membership_kernel(cands, others, interpret=True)
    return multiway_membership_ref(cands, others)
