"""Jitted public wrappers for the intersect kernels with CPU fallback.

The Pallas TPU kernels only lower on TPU backends; everywhere else (this CI
box) we execute either the pure-jnp oracle (fast XLA path) or the kernel in
``interpret=True`` mode (tests do the latter to validate kernel semantics).

Dispatch policy, uniform across all entry points:
  1. on TPU             → native Pallas kernel (batch padded to tile multiples)
  2. ``force_kernel``   → Pallas kernel under interpret=True (CPU CI parity)
  3. otherwise          → pure-jnp reference twin
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.graph.storage import INVALID
from repro.kernels.intersect.intersect import (
    TILE_B,
    fused_extend_kernel,
    fused_verify_kernel,
    lex_bounds_kernel,
    multiway_membership_kernel,
)
from repro.kernels.intersect.ref import (
    fused_extend_ref,
    fused_verify_ref,
    lex_bounds_ref,
    multiway_membership_ref,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x: jax.Array, n: int, fill) -> jax.Array:
    """Pad axis 0 with ``n`` rows of ``fill`` (no-op when n == 0)."""
    if n == 0:
        return x
    pad = jnp.full((n,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def multiway_membership(cands: jax.Array, others: jax.Array, *, force_kernel: bool = False) -> jax.Array:
    """Batched Eq.-2 membership: cands[B, D] ∈ ∩ others[B, E, D]."""
    b = cands.shape[0]
    if _on_tpu() or force_kernel:
        # Pad the batch to the next TILE_B multiple; INVALID candidate rows
        # produce all-False membership, so the pad is inert and sliced off.
        pad = (-b) % TILE_B
        out = multiway_membership_kernel(
            _pad_rows(cands, pad, INVALID),
            _pad_rows(others, pad, INVALID),
            interpret=not _on_tpu(),
        )
        return out[:b]
    return multiway_membership_ref(cands, others)


def fused_extend(
    tab0: jax.Array,
    tab1: jax.Array,
    idx: jax.Array,
    sel: jax.Array,
    ok: jax.Array,
    rows: jax.Array,
    *,
    lt: Tuple[int, ...] = (),
    gt: Tuple[int, ...] = (),
    force_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused probe-select slab gather → multiway intersect → injectivity/order
    filters. Returns (cands[B, D], mask[B, D]); see fused_extend_kernel."""
    if _on_tpu() or force_kernel:
        return fused_extend_kernel(
            tab0, tab1, idx, sel, ok, rows,
            lt=lt, gt=gt, interpret=not _on_tpu(),
        )
    return fused_extend_ref(tab0, tab1, idx, sel, ok, rows, lt=lt, gt=gt)


def fused_verify(
    tab0: jax.Array,
    tab1: jax.Array,
    idx: jax.Array,
    sel: jax.Array,
    ok: jax.Array,
    rows: jax.Array,
    *,
    vpos: int,
    force_kernel: bool = False,
) -> jax.Array:
    """Fused VERIFY membership of rows[:, vpos] across all gathered slabs."""
    if _on_tpu() or force_kernel:
        return fused_verify_kernel(
            tab0, tab1, idx, sel, ok, rows, vpos=vpos, interpret=not _on_tpu()
        )
    return fused_verify_ref(tab0, tab1, idx, sel, ok, rows, vpos=vpos)


def lex_bounds(
    sorted_keys: jax.Array,
    queries: jax.Array,
    *,
    force_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Equal-range (lo, hi) of queries[B, KK] in sorted_keys[CAP, KK]."""
    if _on_tpu() or force_kernel:
        return lex_bounds_kernel(sorted_keys, queries, interpret=not _on_tpu())
    return lex_bounds_ref(sorted_keys, queries)
