"""Deterministic synthetic graph generators (host-side numpy).

The paper benchmarks on LJ/OR/UK/... web-scale graphs; on this CI box we use
scaled-down graphs with matching *shape* characteristics: power-law degree
distributions (social/web graphs), near-regular sparse graphs (road networks),
and clique-heavy graphs (to stress clique queries).
"""
from __future__ import annotations

import numpy as np

from repro.graph.storage import Graph, build_graph


def erdos_renyi(num_vertices: int, avg_degree: float, seed: int = 0) -> Graph:
    """G(n, p) with p chosen for the requested average degree."""
    rng = np.random.default_rng(seed)
    p = min(1.0, avg_degree / max(1, num_vertices - 1))
    # Sample edges in blocks to avoid O(n^2) memory for large n.
    n_expected = int(num_vertices * (num_vertices - 1) / 2 * p)
    m = int(n_expected * 1.2) + 16
    src = rng.integers(0, num_vertices, size=m, dtype=np.int64)
    dst = rng.integers(0, num_vertices, size=m, dtype=np.int64)
    edges = np.stack([src, dst], axis=1)
    edges = edges[src != dst][:n_expected]
    return build_graph(edges, num_vertices)


def powerlaw_graph(num_vertices: int, avg_degree: float, exponent: float = 2.5, seed: int = 0) -> Graph:
    """Configuration-model power-law graph (Chung-Lu sampling).

    Degree weights w_i ∝ i^{-1/(exponent-1)}; edge (u,v) sampled with
    probability ∝ w_u * w_v, matching the paper's social/web workloads.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, num_vertices + 1, dtype=np.float64)
    w = ranks ** (-1.0 / (exponent - 1.0))
    w /= w.sum()
    target_edges = int(num_vertices * avg_degree / 2)
    m = int(target_edges * 1.3) + 16
    src = rng.choice(num_vertices, size=m, p=w)
    dst = rng.choice(num_vertices, size=m, p=w)
    edges = np.stack([src, dst], axis=1)
    edges = edges[src != dst][:target_edges]
    # Relabel randomly so owner hashing (v % P) is unbiased w.r.t. degree.
    perm = rng.permutation(num_vertices)
    edges = perm[edges]
    return build_graph(edges, num_vertices)


def ring_of_cliques(num_cliques: int, clique_size: int) -> Graph:
    """`num_cliques` k-cliques chained in a ring — clique-query stress test."""
    edges = []
    for c in range(num_cliques):
        base = c * clique_size
        for i in range(clique_size):
            for j in range(i + 1, clique_size):
                edges.append((base + i, base + j))
        nxt = ((c + 1) % num_cliques) * clique_size
        edges.append((base, nxt))
    n = num_cliques * clique_size
    return build_graph(np.asarray(edges), n)


def grid_graph(rows: int, cols: int) -> Graph:
    """2-D grid — road-network-like (EU analogue): low, uniform degree."""
    edges = []
    for r in range(rows):
        for c in range(cols):
            v = r * cols + c
            if c + 1 < cols:
                edges.append((v, v + 1))
            if r + 1 < rows:
                edges.append((v, v + cols))
    return build_graph(np.asarray(edges), rows * cols)
