"""Host-side oracles for subgraph-enumeration correctness.

The engine's result counts are validated against networkx's VF2 matcher:
``#instances = #monomorphisms(q -> G) / |Aut(q)|`` — the paper's symmetry
breaking guarantees each subgraph instance is produced exactly once, so the
engine count must equal this quantity exactly.
"""
from __future__ import annotations

import numpy as np
import networkx as nx
from networkx.algorithms import isomorphism as iso

from repro.graph.storage import Graph, to_networkx


def query_to_networkx(query_edges) -> "nx.Graph":
    q = nx.Graph()
    q.add_edges_from([tuple(map(int, e)) for e in query_edges])
    return q


def num_automorphisms(query_edges) -> int:
    q = query_to_networkx(query_edges)
    gm = iso.GraphMatcher(q, q)
    return sum(1 for _ in gm.isomorphisms_iter())


def count_monomorphisms(graph: Graph | "nx.Graph", query_edges) -> int:
    g = graph if isinstance(graph, nx.Graph) else to_networkx(graph)
    q = query_to_networkx(query_edges)
    gm = iso.GraphMatcher(g, q)
    return sum(1 for _ in gm.subgraph_monomorphisms_iter())


def count_instances(graph: Graph | "nx.Graph", query_edges) -> int:
    """#distinct subgraph instances of the query in the data graph."""
    mono = count_monomorphisms(graph, query_edges)
    aut = num_automorphisms(query_edges)
    assert mono % aut == 0, (mono, aut)
    return mono // aut


def enumerate_instances_bruteforce(graph: Graph, query_edges) -> set:
    """Tiny-graph brute force: frozensets of matched vertex tuples (sorted by
    query-vertex id). Only for |V_G| small; used to cross-check the oracle."""
    g = to_networkx(graph)
    q = query_to_networkx(query_edges)
    gm = iso.GraphMatcher(g, q)
    out = set()
    nq = q.number_of_nodes()
    for mapping in gm.subgraph_monomorphisms_iter():
        inv = {qv: gv for gv, qv in mapping.items()}
        out.add(frozenset(inv[i] for i in range(nq)))
    return out
