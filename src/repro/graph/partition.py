"""Random (hash) vertex partitioning — paper Section 2 "Graph Storage".

Vertex ``v`` is owned by shard ``v % num_shards`` (cyclic ≈ random for
arbitrary id assignment), stored with its full adjacency list, exactly like
the paper. For SPMD execution the per-shard padded adjacencies are stacked
into one array ``adj[P, V_per, D_pad]`` that a ``shard_map`` splits along the
leading axis, so every shard's local gather is a static-shape ``take``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.storage import Graph, INVALID


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """Padded adjacency stacked by shard. Owner(v) = v % P, local(v) = v // P."""

    adj: jax.Array  # int32[P, V_per, D_pad]
    deg: jax.Array  # int32[P, V_per]
    num_vertices: int
    num_shards: int

    @property
    def v_per_shard(self) -> int:
        return self.adj.shape[1]

    @property
    def d_pad(self) -> int:
        return self.adj.shape[2]

    def owner(self, vids: jax.Array) -> jax.Array:
        return jnp.where(vids >= 0, vids % self.num_shards, -1)

    def local_index(self, vids: jax.Array) -> jax.Array:
        return jnp.where(vids >= 0, vids // self.num_shards, 0)

    def shard_bytes(self) -> int:
        return int(self.adj.size * 4 + self.deg.size * 4) // self.num_shards

    def tree_flatten(self):
        return (self.adj, self.deg), (self.num_vertices, self.num_shards)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])


def partition_graph(graph: Graph, num_shards: int) -> PartitionedGraph:
    """Split ``graph`` into ``num_shards`` cyclic partitions (host-side)."""
    v = graph.num_vertices
    v_per = (v + num_shards - 1) // num_shards
    d_pad = graph.padded.d_pad

    adj = np.full((num_shards, v_per, d_pad), INVALID, dtype=np.int32)
    deg = np.zeros((num_shards, v_per), dtype=np.int32)

    full_adj = np.asarray(graph.padded.adj)
    full_deg = np.asarray(graph.padded.deg)
    vids = np.arange(v)
    owners = vids % num_shards
    locals_ = vids // num_shards
    adj[owners, locals_] = full_adj
    deg[owners, locals_] = full_deg

    return PartitionedGraph(
        adj=jnp.asarray(adj),
        deg=jnp.asarray(deg),
        num_vertices=v,
        num_shards=num_shards,
    )
