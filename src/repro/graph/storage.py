"""Padded CSR graph storage.

Design notes (TPU adaptation)
-----------------------------
HUGE keeps each partition's adjacency in CSR and serves ``GetNbrs`` RPCs from
it. On TPU every access must be a dense gather, so alongside the classic CSR
pair ``(offsets, nbrs)`` we materialise a *padded adjacency matrix*
``adj[V, D_pad]`` whose rows are the sorted neighbour lists padded with the
sentinel ``INVALID`` (int32 max). Sorted rows + a monotone sentinel mean that

* set intersection (Eq. 2 of the paper) is a vectorised ``searchsorted``;
* padding never produces false positives (INVALID matches nothing);
* symmetry-breaking order filters are plain integer comparisons.

``D_pad`` is the max degree rounded up to a lane multiple (128) so Pallas
kernels can tile rows directly.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel for padded adjacency entries. Larger than any vertex id, so padded
# rows remain sorted and `searchsorted` membership tests are safe.
INVALID = np.int32(np.iinfo(np.int32).max)

_LANE = 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PaddedAdjacency:
    """Dense, padded adjacency: ``adj[v]`` = sorted neighbours of v, INVALID-padded."""

    adj: jax.Array  # int32[V, D_pad]
    deg: jax.Array  # int32[V]

    @property
    def num_vertices(self) -> int:
        return self.adj.shape[0]

    @property
    def d_pad(self) -> int:
        return self.adj.shape[1]

    def neighbors(self, vids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Gather padded neighbour rows for ``vids`` (INVALID rows for invalid ids)."""
        safe = jnp.clip(vids, 0, self.num_vertices - 1)
        rows = jnp.take(self.adj, safe, axis=0)
        degs = jnp.take(self.deg, safe, axis=0)
        ok = (vids >= 0) & (vids < self.num_vertices)
        rows = jnp.where(ok[..., None], rows, INVALID)
        degs = jnp.where(ok, degs, 0)
        return rows, degs

    def tree_flatten(self):
        return (self.adj, self.deg), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected data graph in CSR + padded form (device resident)."""

    offsets: jax.Array  # int32[V+1]
    nbrs: jax.Array  # int32[2E] sorted within each row
    padded: PaddedAdjacency

    @property
    def num_vertices(self) -> int:
        return self.offsets.shape[0] - 1

    @property
    def num_directed_edges(self) -> int:
        return self.nbrs.shape[0]

    @property
    def num_edges(self) -> int:
        return self.nbrs.shape[0] // 2

    @property
    def max_degree(self) -> int:
        return int(np.asarray(jnp.max(self.padded.deg)))

    @property
    def avg_degree(self) -> float:
        return float(self.num_directed_edges) / max(1, self.num_vertices)

    def degree(self, vids: jax.Array) -> jax.Array:
        return jnp.take(self.padded.deg, jnp.clip(vids, 0, self.num_vertices - 1))

    def neighbors(self, vids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        return self.padded.neighbors(vids)

    def has_edge(self, u: jax.Array, v: jax.Array) -> jax.Array:
        """Vectorised edge test via searchsorted on sorted padded rows."""
        rows, _ = self.padded.neighbors(u)
        idx = jax.vmap(jnp.searchsorted)(rows, v)
        idx = jnp.clip(idx, 0, rows.shape[-1] - 1)
        return jnp.take_along_axis(rows, idx[..., None], axis=-1)[..., 0] == v

    def size_bytes(self) -> int:
        return int(
            self.offsets.size * 4 + self.nbrs.size * 4 + self.padded.adj.size * 4 + self.padded.deg.size * 4
        )

    def tree_flatten(self):
        return (self.offsets, self.nbrs, self.padded), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def build_graph(edges: np.ndarray, num_vertices: int, d_pad: int | None = None) -> Graph:
    """Build a :class:`Graph` from an undirected edge array ``int[E, 2]``.

    Self loops and duplicate edges are removed; adjacency is symmetrised and
    sorted. ``d_pad`` defaults to max degree rounded up to 128 lanes.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    # Drop self loops, canonicalise, dedup.
    edges = edges[edges[:, 0] != edges[:, 1]]
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    und = np.unique(np.stack([lo, hi], axis=1), axis=0)
    # Symmetrise.
    both = np.concatenate([und, und[:, ::-1]], axis=0)
    order = np.lexsort((both[:, 1], both[:, 0]))
    both = both[order]
    src, dst = both[:, 0], both[:, 1]

    deg = np.bincount(src, minlength=num_vertices).astype(np.int32)
    offsets = np.zeros(num_vertices + 1, dtype=np.int32)
    np.cumsum(deg, out=offsets[1:])
    nbrs = dst.astype(np.int32)

    max_deg = int(deg.max()) if deg.size else 0
    if d_pad is None:
        d_pad = max(_LANE, _round_up(max(1, max_deg), _LANE))
    if max_deg > d_pad:
        raise ValueError(f"d_pad={d_pad} smaller than max degree {max_deg}")

    adj = np.full((num_vertices, d_pad), INVALID, dtype=np.int32)
    # Row-fill padded adjacency (vectorised scatter).
    row_idx = src
    col_idx = (np.arange(both.shape[0]) - offsets[:-1].astype(np.int64)[src]).astype(np.int64)
    adj[row_idx, col_idx] = nbrs

    return Graph(
        offsets=jnp.asarray(offsets),
        nbrs=jnp.asarray(nbrs),
        padded=PaddedAdjacency(adj=jnp.asarray(adj), deg=jnp.asarray(deg)),
    )


def from_edge_list(edge_list: Iterable[Sequence[int]], num_vertices: int | None = None) -> Graph:
    edges = np.asarray(list(edge_list), dtype=np.int64).reshape(-1, 2)
    if num_vertices is None:
        num_vertices = int(edges.max()) + 1 if edges.size else 0
    return build_graph(edges, num_vertices)


def to_networkx(graph: Graph):
    """Convert to networkx (host-side) for oracle validation."""
    import networkx as nx

    g = nx.Graph()
    g.add_nodes_from(range(graph.num_vertices))
    offsets = np.asarray(graph.offsets)
    nbrs = np.asarray(graph.nbrs)
    for v in range(graph.num_vertices):
        for u in nbrs[offsets[v] : offsets[v + 1]]:
            if v < u:
                g.add_edge(v, int(u))
    return g
