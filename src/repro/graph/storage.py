"""Padded CSR graph storage.

Design notes (TPU adaptation)
-----------------------------
HUGE keeps each partition's adjacency in CSR and serves ``GetNbrs`` RPCs from
it. On TPU every access must be a dense gather, so alongside the classic CSR
pair ``(offsets, nbrs)`` we materialise a *padded adjacency matrix*
``adj[V, D_pad]`` whose rows are the sorted neighbour lists padded with the
sentinel ``INVALID`` (int32 max). Sorted rows + a monotone sentinel mean that

* set intersection (Eq. 2 of the paper) is a vectorised ``searchsorted``;
* padding never produces false positives (INVALID matches nothing);
* symmetry-breaking order filters are plain integer comparisons.

``D_pad`` is the max degree rounded up to a lane multiple (128) so Pallas
kernels can tile rows directly.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel for padded adjacency entries. Larger than any vertex id, so padded
# rows remain sorted and `searchsorted` membership tests are safe.
INVALID = np.int32(np.iinfo(np.int32).max)

_LANE = 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PaddedAdjacency:
    """Dense, padded adjacency: ``adj[v]`` = sorted neighbours of v, INVALID-padded."""

    adj: jax.Array  # int32[V, D_pad]
    deg: jax.Array  # int32[V]

    def __post_init__(self):
        # The Pallas fused kernels tile adjacency rows directly, so D_pad must
        # be a lane multiple — enforced here so a hand-built adjacency can't
        # silently violate what build_graph guarantees.
        if hasattr(self.adj, "ndim") and self.adj.ndim == 2:
            d_pad = self.adj.shape[1]
            if d_pad % _LANE != 0:
                raise ValueError(
                    f"PaddedAdjacency d_pad={d_pad} is not a multiple of the "
                    f"{_LANE}-lane tile (build_graph rounds up; do the same)"
                )

    @property
    def num_vertices(self) -> int:
        return self.adj.shape[0]

    @property
    def d_pad(self) -> int:
        return self.adj.shape[1]

    def neighbors(self, vids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Gather padded neighbour rows for ``vids`` (INVALID rows for invalid ids)."""
        safe = jnp.clip(vids, 0, self.num_vertices - 1)
        rows = jnp.take(self.adj, safe, axis=0)
        degs = jnp.take(self.deg, safe, axis=0)
        ok = (vids >= 0) & (vids < self.num_vertices)
        rows = jnp.where(ok[..., None], rows, INVALID)
        degs = jnp.where(ok, degs, 0)
        return rows, degs

    def tree_flatten(self):
        return (self.adj, self.deg), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected data graph in CSR + padded form (device resident)."""

    offsets: jax.Array  # int32[V+1]
    nbrs: jax.Array  # int32[2E] sorted within each row
    padded: PaddedAdjacency

    @property
    def num_vertices(self) -> int:
        return self.offsets.shape[0] - 1

    @property
    def num_directed_edges(self) -> int:
        return self.nbrs.shape[0]

    @property
    def num_edges(self) -> int:
        return self.nbrs.shape[0] // 2

    @property
    def max_degree(self) -> int:
        return int(np.asarray(jnp.max(self.padded.deg)))

    @property
    def avg_degree(self) -> float:
        return float(self.num_directed_edges) / max(1, self.num_vertices)

    def degree(self, vids: jax.Array) -> jax.Array:
        return jnp.take(self.padded.deg, jnp.clip(vids, 0, self.num_vertices - 1))

    def neighbors(self, vids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        return self.padded.neighbors(vids)

    def has_edge(self, u: jax.Array, v: jax.Array) -> jax.Array:
        """Vectorised edge test via searchsorted on sorted padded rows.

        Broadcast-safe over scalar, 1-D, and batched inputs: ``vmap`` requires
        rank ≥ 1, so the padded rows and targets are flattened to one batch
        axis, searched, and reshaped back to the broadcast shape of ``u``/``v``.
        """
        u = jnp.asarray(u, jnp.int32)
        v = jnp.asarray(v, jnp.int32)
        rows, _ = self.padded.neighbors(u)
        batch_shape = jnp.broadcast_shapes(u.shape, v.shape)
        rows = jnp.broadcast_to(rows, batch_shape + rows.shape[-1:])
        vb = jnp.broadcast_to(v, batch_shape)
        flat_rows = rows.reshape(-1, rows.shape[-1])
        flat_v = vb.reshape(-1)
        idx = jax.vmap(jnp.searchsorted)(flat_rows, flat_v)
        idx = jnp.clip(idx, 0, flat_rows.shape[-1] - 1)
        found = jnp.take_along_axis(flat_rows, idx[:, None], axis=-1)[:, 0]
        return (found == flat_v).reshape(batch_shape)

    def size_bytes(self) -> int:
        return int(
            self.offsets.size * 4 + self.nbrs.size * 4 + self.padded.adj.size * 4 + self.padded.deg.size * 4
        )

    def tree_flatten(self):
        return (self.offsets, self.nbrs, self.padded), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def build_graph(edges: np.ndarray, num_vertices: int, d_pad: int | None = None) -> Graph:
    """Build a :class:`Graph` from an undirected edge array ``int[E, 2]``.

    Self loops and duplicate edges are removed; adjacency is symmetrised and
    sorted. ``d_pad`` defaults to max degree rounded up to 128 lanes.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    # Drop self loops, canonicalise, dedup.
    edges = edges[edges[:, 0] != edges[:, 1]]
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    und = np.unique(np.stack([lo, hi], axis=1), axis=0)
    # Symmetrise.
    both = np.concatenate([und, und[:, ::-1]], axis=0)
    order = np.lexsort((both[:, 1], both[:, 0]))
    both = both[order]
    src, dst = both[:, 0], both[:, 1]

    deg = np.bincount(src, minlength=num_vertices).astype(np.int32)
    offsets = np.zeros(num_vertices + 1, dtype=np.int32)
    np.cumsum(deg, out=offsets[1:])
    nbrs = dst.astype(np.int32)

    max_deg = int(deg.max()) if deg.size else 0
    if d_pad is None:
        d_pad = max(_LANE, _round_up(max(1, max_deg), _LANE))
    else:
        # An explicit d_pad must still satisfy the module invariant (lane-
        # multiple rows: the Pallas kernels tile on it) — round up rather than
        # letting e.g. d_pad=3 pass validation and break kernels downstream.
        d_pad = max(_LANE, _round_up(int(d_pad), _LANE))
    if max_deg > d_pad:
        raise ValueError(f"d_pad={d_pad} smaller than max degree {max_deg}")

    adj = np.full((num_vertices, d_pad), INVALID, dtype=np.int32)
    # Row-fill padded adjacency (vectorised scatter).
    row_idx = src
    col_idx = (np.arange(both.shape[0]) - offsets[:-1].astype(np.int64)[src]).astype(np.int64)
    adj[row_idx, col_idx] = nbrs

    return Graph(
        offsets=jnp.asarray(offsets),
        nbrs=jnp.asarray(nbrs),
        padded=PaddedAdjacency(adj=jnp.asarray(adj), deg=jnp.asarray(deg)),
    )


# ---------------------------------------------------------------------------
# Streaming updates (delta-plan substrate; DESIGN.md §Delta-plans)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GraphUpdateBatch:
    """A batch of graph mutations. Only edge inserts for now; ``kind`` keeps
    the wire format ready for deletes (delta flows would then also subtract
    matches, which needs old-epoch *adjacency* rather than the new-minus-delta
    reconstruction inserts allow)."""

    edges: np.ndarray  # int[E, 2] undirected; self loops / dups tolerated
    kind: str = "insert"

    def __post_init__(self):
        if self.kind != "insert":
            raise NotImplementedError(
                f"GraphUpdateBatch kind={self.kind!r}: only 'insert' is "
                "supported (deletes need old-epoch adjacency snapshots)"
            )

    @property
    def num_edges(self) -> int:
        return int(np.asarray(self.edges).reshape(-1, 2).shape[0])


@dataclasses.dataclass(frozen=True)
class AppliedUpdates:
    """Result of :func:`apply_updates`.

    ``graph`` is the post-batch graph G_new; ``delta`` is a :class:`Graph`
    over the *genuinely new* edges only (already-present edges and dups are
    dropped), which serves both as the delta scan source and as the
    old-epoch membership filter: for pure inserts,
    ``N_old(v) = N_new(v) \\ N_delta(v)``."""

    graph: Graph
    delta: Graph
    edges: np.ndarray       # int32[E_new, 2] canonical genuinely-new edges
    touched: np.ndarray     # int32[T] vertex ids whose adjacency rows changed

    @property
    def num_new_edges(self) -> int:
        return int(self.edges.shape[0])


def _canonical_new_edges(graph: Graph, batch: GraphUpdateBatch) -> np.ndarray:
    """Canonicalise a batch against the current graph: drop self loops,
    duplicates, out-of-range endpoints (an error), and edges already present."""
    edges = np.asarray(batch.edges, dtype=np.int64).reshape(-1, 2)
    if edges.size == 0:
        return np.zeros((0, 2), np.int64)
    v = graph.num_vertices
    if edges.min() < 0 or edges.max() >= v:
        raise ValueError(
            f"update batch references vertices outside [0, {v}) "
            "(vertex inserts are not supported; grow the graph by rebuild)"
        )
    edges = edges[edges[:, 0] != edges[:, 1]]
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    und = np.unique(np.stack([lo, hi], axis=1), axis=0)
    if und.size == 0:
        return und.reshape(0, 2)
    # Drop edges already in the graph (host CSR membership per row).
    offsets = np.asarray(graph.offsets)
    nbrs = np.asarray(graph.nbrs)
    starts = offsets[und[:, 0]]
    ends = offsets[und[:, 0] + 1]
    present = np.zeros(und.shape[0], bool)
    for i, (a, b) in enumerate(und):
        row = nbrs[starts[i] : ends[i]]
        j = np.searchsorted(row, b)
        present[i] = j < row.shape[0] and row[j] == b
    return und[~present]


def apply_updates(graph: Graph, batch: GraphUpdateBatch) -> AppliedUpdates:
    """Apply an edge-insert batch, rebuilding only the affected rows.

    CSR: the new directed neighbours are spliced into ``nbrs`` with one
    vectorised ``np.insert`` (positions computed by per-row searchsorted) and
    offsets re-accumulated. Padded adjacency: only the touched rows are
    re-padded and scattered into a copy; when a touched row overflows
    ``d_pad``, the matrix grows by whole lane multiples (128) so the kernel
    tiling invariant survives the update."""
    new_edges = _canonical_new_edges(graph, batch)
    v = graph.num_vertices
    delta = build_graph(new_edges, v)
    if new_edges.shape[0] == 0:
        return AppliedUpdates(
            graph=graph, delta=delta,
            edges=new_edges.astype(np.int32),
            touched=np.zeros((0,), np.int32),
        )

    offsets = np.asarray(graph.offsets).astype(np.int64)
    nbrs = np.asarray(graph.nbrs)
    deg = np.asarray(graph.padded.deg).copy()

    # Directed view of the inserts, sorted by (row, value) so np.insert keeps
    # every row sorted even when one row receives several new neighbours.
    src = np.concatenate([new_edges[:, 0], new_edges[:, 1]])
    dst = np.concatenate([new_edges[:, 1], new_edges[:, 0]]).astype(np.int32)
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    # Insert position of each new neighbour inside its row, relative to the
    # *original* flat nbrs array (np.insert semantics).
    pos = np.empty(src.shape[0], np.int64)
    for i in range(src.shape[0]):
        row = nbrs[offsets[src[i]] : offsets[src[i] + 1]]
        pos[i] = offsets[src[i]] + np.searchsorted(row, dst[i])
    new_nbrs = np.insert(nbrs, pos, dst)

    add_cnt = np.bincount(src, minlength=v).astype(np.int32)
    new_deg = deg + add_cnt
    new_offsets = np.zeros(v + 1, dtype=np.int32)
    np.cumsum(new_deg, out=new_offsets[1:])

    # Padded adjacency: grow columns by lane multiples if any row overflowed,
    # then rebuild only the touched rows from the fresh CSR.
    touched = np.unique(src).astype(np.int32)
    adj = np.asarray(graph.padded.adj)
    max_deg = int(new_deg.max())
    d_pad = adj.shape[1]
    if max_deg > d_pad:
        d_pad = _round_up(max_deg, _LANE)
        adj = np.pad(adj, ((0, 0), (0, d_pad - adj.shape[1])),
                     constant_values=INVALID)
    else:
        adj = adj.copy()
    for t in touched:
        row = new_nbrs[new_offsets[t] : new_offsets[t + 1]]
        adj[t, : row.shape[0]] = row
        adj[t, row.shape[0] :] = INVALID

    new_graph = Graph(
        offsets=jnp.asarray(new_offsets),
        nbrs=jnp.asarray(new_nbrs.astype(np.int32)),
        padded=PaddedAdjacency(adj=jnp.asarray(adj), deg=jnp.asarray(new_deg)),
    )
    return AppliedUpdates(
        graph=new_graph, delta=delta,
        edges=new_edges.astype(np.int32), touched=touched,
    )


def from_edge_list(edge_list: Iterable[Sequence[int]], num_vertices: int | None = None) -> Graph:
    edges = np.asarray(list(edge_list), dtype=np.int64).reshape(-1, 2)
    if num_vertices is None:
        num_vertices = int(edges.max()) + 1 if edges.size else 0
    return build_graph(edges, num_vertices)


def to_networkx(graph: Graph):
    """Convert to networkx (host-side) for oracle validation."""
    import networkx as nx

    g = nx.Graph()
    g.add_nodes_from(range(graph.num_vertices))
    offsets = np.asarray(graph.offsets)
    nbrs = np.asarray(graph.nbrs)
    for v in range(graph.num_vertices):
        for u in nbrs[offsets[v] : offsets[v + 1]]:
            if v < u:
                g.add_edge(v, int(u))
    return g
