"""Graph substrate: device-resident CSR storage, partitioning, generators.

The data graph is stored in padded CSR form (sorted adjacency, sentinel
padding) so that every operator in the HUGE engine is a dense, vectorisable
JAX computation. Partitioning follows the paper's random (hash) vertex
partitioning (Section 2 of the paper): vertex ``v`` lives on shard
``v % num_shards`` together with its full adjacency list.
"""
from repro.graph.storage import (
    INVALID,
    AppliedUpdates,
    Graph,
    GraphUpdateBatch,
    PaddedAdjacency,
    apply_updates,
    build_graph,
    from_edge_list,
)
from repro.graph.partition import PartitionedGraph, partition_graph
from repro.graph.generators import (
    erdos_renyi,
    powerlaw_graph,
    ring_of_cliques,
    grid_graph,
)

__all__ = [
    "INVALID",
    "AppliedUpdates",
    "Graph",
    "GraphUpdateBatch",
    "PaddedAdjacency",
    "apply_updates",
    "build_graph",
    "from_edge_list",
    "PartitionedGraph",
    "partition_graph",
    "erdos_renyi",
    "powerlaw_graph",
    "ring_of_cliques",
    "grid_graph",
]
