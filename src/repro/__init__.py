"""repro — HUGE (push/pull-hybrid subgraph enumeration) on JAX/TPU, plus an
LM training/serving framework built on the paper's communication/scheduling
ideas. See README.md, DESIGN.md, EXPERIMENTS.md."""

__version__ = "1.0.0"
