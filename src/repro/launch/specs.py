"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every cell.

Everything here is abstract — weak-type-correct, shardable, zero allocation —
so the dry-run can lower+compile full-size models on 512 host devices.

Per-family shape conventions (documented in DESIGN.md §Shape-conventions):
  * [vlm]/[audio-decoder-only]: ``frontend_len`` patch/frame embeddings are
    prepended; text tokens fill the remaining ``seq_len − frontend_len``.
  * enc-dec (seamless): encoder frames = seq_len/2, decoder tokens = seq_len/2
    (total backbone positions = seq_len).
  * decode shapes lower ``decode_step`` with a full-size KV cache; batch=1
    long-context cells shard the cache's *sequence* dim over "data" instead
    of the unshardable batch dim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.core.adaptive_schedule import choose_microbatches
from repro.models import transformer as T
from repro.models.partitioning import param_shardings
from repro.train.optimizer import AdamWConfig, state_shapes
from repro.train.train_step import TrainConfig, make_train_step


def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp(mesh: Mesh) -> int:
    n = 1
    for a in _batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def _tokens_layout(cfg: T.ModelConfig, shape: ShapeSpec) -> Tuple[int, int, int]:
    """(text_len, frontend_len, enc_len) for this arch × shape."""
    s = shape.seq_len
    if cfg.encoder_layers:
        return s // 2, s // 2, s // 2
    if cfg.frontend:
        fl = cfg.frontend_len
        return s - fl, fl, 0
    return s, 0, 0


def train_config_for(cfg: T.ModelConfig, shape: ShapeSpec, mesh: Mesh) -> TrainConfig:
    big = cfg.param_count() > 100e9
    dp = _dp(mesh)
    micro = choose_microbatches(
        cfg, shape.global_batch, shape.seq_len,
        device_count=dp,                       # model shards see the same tokens
        budget_bytes=6 << 30,
    ).num_microbatches
    micro = min(micro, max(1, shape.global_batch // dp))  # keep ≥1 seq/shard
    return TrainConfig(
        adamw=AdamWConfig(state_dtype="bfloat16" if big else "float32"),
        microbatches=micro,
    )


def use_fsdp(cfg: T.ModelConfig) -> bool:
    return cfg.param_count() > 2e10


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def batch_specs(cfg: T.ModelConfig, shape: ShapeSpec, microbatches: int) -> Dict[str, Any]:
    text, fl, enc = _tokens_layout(cfg, shape)
    b = shape.global_batch
    dt = jnp.bfloat16

    def shp(*dims):
        if microbatches > 1:
            return (microbatches, dims[0] // microbatches) + dims[1:]
        return dims

    batch: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct(shp(b, text), jnp.int32),
    }
    if cfg.encoder_layers:
        batch["frontend"] = jax.ShapeDtypeStruct(shp(b, enc, cfg.d_model), dt)
    elif cfg.frontend:
        batch["frontend"] = jax.ShapeDtypeStruct(shp(b, fl, cfg.d_model), dt)
    return batch


def batch_shardings(cfg: T.ModelConfig, shape: ShapeSpec, microbatches: int, mesh: Mesh):
    axes = _batch_axes(mesh)
    dp = _dp(mesh)
    spec_b = axes if (axes and shape.global_batch % dp == 0) else None

    def mk(ndim):
        lead = (None,) if microbatches > 1 else ()
        rest = (None,) * (ndim - len(lead) - 1)
        return NamedSharding(mesh, P(*lead, spec_b, *rest))

    out = {"tokens": mk(2 + (1 if microbatches > 1 else 0))}
    if cfg.encoder_layers or cfg.frontend:
        out["frontend"] = mk(3 + (1 if microbatches > 1 else 0))
    return out


def cache_specs(cfg: T.ModelConfig, shape: ShapeSpec):
    return jax.eval_shape(lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))


def cache_shardings(cfg: T.ModelConfig, shape: ShapeSpec, mesh: Mesh):
    axes = _batch_axes(mesh)
    dp = _dp(mesh)
    b = shape.global_batch
    b_ax = axes if (axes and b % dp == 0) else None
    seq_ax = "data" if (b_ax is None and "data" in mesh.axis_names) else None
    tp = "model" if "model" in mesh.axis_names else None

    tp_size = mesh.shape.get("model", 1)

    def rule(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        nd = len(leaf.shape)
        if "len" in names[-1:]:
            return NamedSharding(mesh, P(None))
        if "attn" in names or "memory" in names:
            # [ng|L, B, S, KV, hd] — TP lands on whichever of (KV, hd)
            # divides the model axis (few-KV-head GQA shards the head dim).
            kv, hd = leaf.shape[-2], leaf.shape[-1]
            if tp and kv % tp_size == 0:
                return NamedSharding(mesh, P(None, b_ax, seq_ax, tp, None))
            if tp and hd % tp_size == 0:
                return NamedSharding(mesh, P(None, b_ax, seq_ax, None, tp))
            return NamedSharding(mesh, P(None, b_ax, seq_ax, None, None))
        if "mamba" in names:
            if nd == 4 and leaf.shape[-1] == cfg.ssm_state:   # h [ng,B,di,state]
                return NamedSharding(mesh, P(None, b_ax, tp, None))
            return NamedSharding(mesh, P(None, b_ax, None, tp))  # conv tail
        if "rwkv" in names:
            if nd == 5:   # S [ng,B,H,hd,hd]
                return NamedSharding(mesh, P(None, b_ax, tp, None, None))
            return NamedSharding(mesh, P(None, b_ax, None))       # x_prev
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(rule, cache_specs(cfg, shape))


# ---------------------------------------------------------------------------
# Cell assembly: (step_fn, arg shapes, in_shardings, donate)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    fn: Any
    args: Tuple
    in_shardings: Tuple
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


def input_specs(cfg: T.ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Cell:
    fsdp = use_fsdp(cfg)
    p_shapes = T.param_shapes(cfg)
    p_sh = param_shardings(cfg, p_shapes, mesh, fsdp=fsdp)

    if shape.kind == "train":
        tc = train_config_for(cfg, shape, mesh)
        o_shapes = state_shapes(tc.adamw, p_shapes)
        o_sh = {
            "m": param_shardings(cfg, p_shapes, mesh, fsdp=fsdp),
            "v": param_shardings(cfg, p_shapes, mesh, fsdp=fsdp),
            "step": NamedSharding(mesh, P()),
        }
        b_shapes = batch_specs(cfg, shape, tc.microbatches)
        b_sh = batch_shardings(cfg, shape, tc.microbatches, mesh)
        fn = make_train_step(cfg, tc)
        return Cell(
            fn=fn,
            args=(p_shapes, o_shapes, b_shapes),
            in_shardings=(p_sh, o_sh, b_sh),
            donate_argnums=(0, 1),
            meta={"microbatches": tc.microbatches, "fsdp": fsdp,
                  "opt_state_dtype": tc.adamw.state_dtype},
        )

    if shape.kind == "prefill":
        b_shapes = batch_specs(cfg, shape, 1)
        b_sh = batch_shardings(cfg, shape, 1, mesh)
        text, fl, enc = _tokens_layout(cfg, shape)
        max_len = text + (fl if (cfg.frontend and not cfg.encoder_layers) else 0)

        def fn(params, batch):
            return T.prefill(cfg, params, batch, max_len)

        return Cell(fn=fn, args=(p_shapes, b_shapes), in_shardings=(p_sh, b_sh),
                    donate_argnums=(), meta={"fsdp": fsdp, "max_len": max_len})

    # decode
    c_shapes = cache_specs(cfg, shape)
    c_sh = cache_shardings(cfg, shape, mesh)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    axes = _batch_axes(mesh)
    dp = _dp(mesh)
    tok_sh = NamedSharding(
        mesh, P(axes if (axes and shape.global_batch % dp == 0) else None, None)
    )

    def fn(params, cache, tokens, p):
        return T.decode_step(cfg, params, cache, tokens, p)

    return Cell(
        fn=fn,
        args=(p_shapes, c_shapes, tok, pos),
        in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
        donate_argnums=(1,),
        meta={"fsdp": fsdp, "cache_len": shape.seq_len},
    )
