"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis`` gives per-device HLO FLOPs and bytes accessed; collective
bytes are NOT in cost_analysis, so we parse the post-SPMD HLO text and sum
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (operand shapes are printed inline in HLO
long text: ``= bf16[512,128]{1,0} all-gather(bf16[32,128]{1,0} %p)``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^)]*)\)"
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes (per device, one step)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        kind, operands = m.group(1), m.group(2)
        total = 0
        for sm in _SHAPE_RE.finditer(operands):
            total += _shape_bytes(sm.group(1), sm.group(2))
        if total == 0:
            # fall back to the result shape just before the '='
            pre = hlo_text[max(0, m.start() - 200) : m.start()]
            shapes = list(_SHAPE_RE.finditer(pre))
            if shapes:
                total = _shape_bytes(shapes[-1].group(1), shapes[-1].group(2))
        out[kind] += total
        counts[kind] += 1
    out["_counts"] = counts  # type: ignore
    out["total"] = sum(v for k, v in out.items() if k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective operand bytes
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        vals = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(cost: Dict, coll: Dict, *, peak_flops: float, hbm_bw: float, link_bw: float) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.get("total", 0.0))
    return RooflineTerms(
        flops=flops,
        hbm_bytes=nbytes,
        coll_bytes=cbytes,
        compute_s=flops / peak_flops,
        memory_s=nbytes / hbm_bw,
        collective_s=cbytes / link_bw,
    )
