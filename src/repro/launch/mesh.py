"""Production meshes.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests/benches must keep seeing 1 device.

Mesh topology (TPU v5e): a pod is a 16×16 mesh → axes (data=16, model=16);
multi-pod adds the leading ``pod`` axis over the inter-pod DCI links. DP runs
over pod×data, TP/EP over model / data respectively (see
models/partitioning.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (intra-pod)
DCI_BW = 25e9                     # bytes/s per link (inter-pod, conservative)
HBM_BYTES = 16 << 30              # v5e HBM per chip
