"""Restart-safe training driver.

``python -m repro.launch.train --arch granite-3-8b --smoke --steps 50``

Fault tolerance: resumes from the latest *valid* checkpoint (corrupt/partial
ones are digest-rejected); checkpoints are written asynchronously off the
step path; `--fail-at N` injects a hard crash after step N for the restart
tests. Elastic: the mesh is built from whatever devices exist, and the
checkpoint is resharded onto it (train/elastic.py).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.core.adaptive_schedule import choose_microbatches
from repro.models import sharding as shd
from repro.models import transformer as T
from repro.models.partitioning import count_params, param_shardings
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, PrefetchLoader
from repro.train.elastic import make_mesh_from_available, reshard_checkpoint
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_all, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--memory-budget-gb", type=float, default=4.0)
    ap.add_argument("--fail-at", type=int, default=-1, help="inject crash after step N")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_mesh_from_available(model_axis=args.model_axis)
    dp = mesh.shape["data"]

    # BFS/DFS-adaptive microbatching (paper Alg. 5 applied to training)
    decision = choose_microbatches(
        cfg, args.global_batch, args.seq_len, device_count=dp,
        budget_bytes=int(args.memory_budget_gb * (1 << 30)),
    )
    micro = min(decision.num_microbatches, max(1, args.global_batch // dp))
    tc = TrainConfig(
        adamw=AdamWConfig(learning_rate=args.lr, warmup_steps=10, total_steps=args.steps),
        microbatches=micro,
    )
    print(f"[train] {cfg.name}: {decision.note}, microbatches={micro}, mesh={dict(mesh.shape)}")

    start_step = 0
    with shd.activate(mesh), mesh:
        if args.ckpt_dir and (latest := ckpt.latest_step(args.ckpt_dir)) is not None:
            print(f"[train] resuming from valid checkpoint step {latest}")
            params, opt_state, extra = reshard_checkpoint(
                args.ckpt_dir, latest, cfg, tc, mesh
            )
            start_step = latest
        else:
            params, opt_state = init_all(cfg, tc, jax.random.key(args.seed))
            p_sh = param_shardings(cfg, params, mesh)
            params = jax.device_put(params, p_sh)
        print(f"[train] params: {count_params(params):,}")

        step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
        dc = DataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq_len,
            global_batch=args.global_batch, microbatches=micro, seed=args.seed,
            frontend=cfg.frontend or ("audio" if cfg.encoder_layers else None),
            frontend_len=max(cfg.frontend_len, 8), d_model=cfg.d_model,
        )
        loader = PrefetchLoader(dc, start_step=start_step)
        t0 = time.time()
        tokens_done = 0
        try:
            for step in range(start_step, args.steps):
                batch = next(loader)
                jb = {k: jnp.asarray(v) for k, v in batch.items()}
                if "frontend" in jb:
                    jb["frontend"] = jb["frontend"].astype(jnp.bfloat16)
                params, opt_state, metrics = step_fn(params, opt_state, jb)
                tokens_done += args.global_batch * args.seq_len
                if (step + 1) % args.log_every == 0 or step == start_step:
                    dt = time.time() - t0
                    print(
                        f"step {step + 1:5d} loss={float(metrics['loss']):.4f} "
                        f"gnorm={float(metrics['grad_norm']):.3f} "
                        f"lr={float(metrics['lr']):.2e} tok/s={tokens_done / max(dt, 1e-9):,.0f} "
                        f"stalls={loader.stalls}"
                    )
                if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                    ckpt.save_async(args.ckpt_dir, step + 1, params, opt_state)
                if args.fail_at >= 0 and step + 1 >= args.fail_at:
                    print(f"[train] injected failure at step {step + 1}", flush=True)
                    os._exit(42)
        finally:
            loader.close()
        if args.ckpt_dir:
            ckpt.wait_pending(args.ckpt_dir)
            if ckpt.latest_step(args.ckpt_dir) != args.steps:
                ckpt.save(args.ckpt_dir, args.steps, params, opt_state)
        print(f"[train] done: final loss {float(metrics['loss']):.4f}")
        return float(metrics["loss"])


if __name__ == "__main__":
    main()
