"""Subgraph-enumeration driver (the paper's own workload).

``python -m repro.launch.enumerate --query q1 --vertices 4096 --machines 8``
runs the full HUGE pipeline: optimiser → dataflow → BFS/DFS-adaptive
scheduler → count, with Table-1-style communication/memory accounting.
"""
from __future__ import annotations

import argparse

from repro.configs.huge_enum import EnumConfig
from repro.core.engine import EngineConfig, HugeEngine
from repro.core.optimizer import optimal_plan
from repro.core.cost import GraphStats
from repro.core.dataflow import translate
from repro.core.query import PAPER_QUERIES
from repro.graph import powerlaw_graph


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", default="q1", choices=list(PAPER_QUERIES))
    ap.add_argument("--vertices", type=int, default=1 << 13)
    ap.add_argument("--avg-degree", type=float, default=8.0)
    ap.add_argument("--machines", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--queue-capacity", type=int, default=1 << 18)
    ap.add_argument("--cache-capacity", type=int, default=1 << 14)
    ap.add_argument("--space", default="huge",
                    choices=["huge", "bigjoin", "benu", "rads", "seed", "starjoin"])
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--verify", action="store_true", help="check against networkx")
    args = ap.parse_args(argv)

    graph = powerlaw_graph(args.vertices, args.avg_degree, seed=args.seed)
    query = PAPER_QUERIES[args.query]
    plan = optimal_plan(query, GraphStats.from_graph(graph), args.machines, args.space)
    print(plan.describe())
    flow = translate(plan)
    print(flow.describe())

    cfg = EngineConfig(
        batch_size=args.batch_size,
        queue_capacity=args.queue_capacity,
        cache_capacity=args.cache_capacity,
        num_machines=args.machines,
    )
    engine = HugeEngine(graph, cfg)
    res = engine.run(flow)
    s = res.stats
    print(
        f"\n[enumerate] {args.query} on |V|={args.vertices} (space={args.space}): "
        f"count={res.count}\n"
        f"  T={s.wall_time:.2f}s (T_R={s.compute_time:.2f}s, T_C={s.comm_time:.2f}s)\n"
        f"  C: pulled={s.pulled_bytes / 1e6:.2f}MB pushed={s.pushed_bytes / 1e6:.2f}MB "
        f"cache-hit-rate={s.hit_rate:.2%}\n"
        f"  M: peak queue {s.peak_queue_bytes / 1e6:.2f}MB ({s.peak_queue_rows} rows)"
    )
    if args.verify:
        from repro.graph.oracle import count_instances
        oracle = count_instances(graph, list(query.edges))
        print(f"  oracle={oracle}  MATCH={oracle == res.count}")
        assert oracle == res.count
    return res.count


if __name__ == "__main__":
    main()
