import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). DRYRUN_DEVICES overrides for CI-scale self-tests.
if os.environ.get("DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['DRYRUN_DEVICES']}"
    )

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware: the sharding is coherent (no
GSPMD errors), the collective schedule exists, memory_analysis fits, and
cost_analysis yields the roofline terms (§Roofline reads the JSON written
here).

Usage:
  python -m repro.launch.dryrun                         # all cells, both meshes
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --skip-existing         # resume a sweep
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_NAMES, SHAPES, get_config, shape_skip_reason
from repro.launch import hlo_counter
from repro.launch.mesh import (
    DCI_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh,
)
from repro.launch.specs import input_specs
from repro.models import sharding as shd

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "dryrun")


def _make_mesh(multi: bool):
    """Production mesh; scaled-down fallback when DRYRUN_DEVICES < 512 (CI
    self-tests only — the deliverable sweep runs at 512)."""
    n = len(jax.devices())
    need = 512 if multi else 256
    if n >= need:
        return make_production_mesh(multi_pod=multi)
    if multi:
        model = max(2, n // 4)
        return jax.make_mesh((2, n // (2 * model), model), ("pod", "data", "model"))
    model = max(2, n // 2)
    return jax.make_mesh((n // model, model), ("data", "model"))


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    mesh = _make_mesh(multi)
    chips = int(len(jax.devices()))
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "chips": chips, "ok": False,
    }
    skip = shape_skip_reason(arch, shape_name)
    if skip:
        rec["skip"] = skip
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json"), "w") as f:
            json.dump(rec, f, indent=2)
        return rec
    t0 = time.time()
    try:
        with shd.activate(mesh), mesh:
            cell = input_specs(cfg, shape, mesh)
            jfn = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                donate_argnums=cell.donate_argnums,
            )
            lowered = jfn.lower(*cell.args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
            mem = compiled.memory_analysis()
            print(mem)   # proves it fits
            cost_list = compiled.cost_analysis()
            cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
            print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
            hlo = compiled.as_text()
            # Exact static counts (XLA's cost_analysis counts loop bodies once
            # — hlo_counter multiplies by the known trip counts).
            counts = hlo_counter.analyze(hlo)
            link_bw = DCI_BW if multi else ICI_BW
            compute_s = counts.flops / PEAK_FLOPS_BF16
            memory_s = counts.bytes / HBM_BW
            collective_s = counts.coll_total / link_bw
            dominant = max(
                [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
                key=lambda kv: kv[1],
            )[0]
            # MODEL_FLOPS: 6·N_active·tokens (train) / 2·N_active·tokens (fwd)
            n_active = cfg.active_param_count()
            tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
            mult = 6 if shape.kind == "train" else 2
            model_flops_dev = mult * n_active * tokens / chips
            rec.update(
                ok=True,
                meta=cell.meta,
                memory={
                    k: int(getattr(mem, k))
                    for k in (
                        "argument_size_in_bytes",
                        "output_size_in_bytes",
                        "temp_size_in_bytes",
                        "generated_code_size_in_bytes",
                    )
                    if hasattr(mem, k)
                },
                xla_cost={k: float(cost[k]) for k in ("flops", "bytes accessed") if k in cost},
                counted={
                    "flops": counts.flops,
                    "hbm_bytes": counts.bytes,
                    "collective_bytes": counts.coll,
                    "collective_calls": counts.coll_calls,
                },
                model_flops_per_device=model_flops_dev,
                useful_ratio=model_flops_dev / max(counts.flops, 1.0),
                roofline={
                    "compute_s": compute_s,
                    "memory_s": memory_s,
                    "collective_s": collective_s,
                    "dominant": dominant,
                },
            )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json"), "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_NAMES + ["all"], nargs="?")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + ["all"], nargs="?")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=REPORT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch in (None, "all") else [args.arch]
    shapes = list(SHAPES) if args.shape in (None, "all") else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                out_file = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}.json")
                if args.skip_existing and os.path.exists(out_file):
                    with open(out_file) as f:
                        rec = json.load(f)
                    if rec.get("ok") or rec.get("skip"):
                        results.append(rec)
                        print(f"[cached] {arch} × {shape} × {mesh_kind}")
                        continue
                print(f"=== {arch} × {shape} × {mesh_kind}", flush=True)
                rec = run_cell(arch, shape, mesh_kind, args.out)
                status = "OK" if rec.get("ok") else rec.get("skip") or "FAIL"
                print(
                    f"--> {status}  lower={rec.get('lower_s', '-')}s "
                    f"compile={rec.get('compile_s', '-')}s "
                    f"dominant={rec.get('roofline', {}).get('dominant', '-')}",
                    flush=True,
                )
                if not rec.get("ok") and not rec.get("skip"):
                    print(rec.get("error"), flush=True)
                results.append(rec)

    ok = sum(1 for r in results if r.get("ok"))
    skipped = sum(1 for r in results if r.get("skip"))
    failed = [r for r in results if not r.get("ok") and not r.get("skip")]
    print(f"\n=== dry-run summary: {ok} ok / {skipped} skipped / {len(failed)} failed")
    for r in failed:
        print(f"  FAIL {r['arch']} × {r['shape']} × {r['mesh']}: {r.get('error')}")
    return 0 if not failed else 1


if __name__ == "__main__":
    raise SystemExit(main())
