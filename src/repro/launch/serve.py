"""Serving drivers.

LM batch serving (the original entry; default when no mode is given):

    python -m repro.launch.serve lm --arch rwkv6-7b --smoke

Multi-tenant graph service (subgraph-matching-as-a-service — N tenants'
enumeration queries multiplexed onto one shared engine, DESIGN.md
§Graph-service):

    PYTHONPATH=src python -m repro.launch.serve graph --tenants 3 --requests 2
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def lm_main(argv=None):
    import jax

    from repro.configs import ARCH_NAMES, get_config, smoke_config
    from repro.models import transformer as T
    from repro.serve.engine import BatchedServer, Request, ServeConfig

    ap = argparse.ArgumentParser(prog="repro.launch.serve lm")
    ap.add_argument("--arch", default="granite-3-8b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(args.seed)
    params = T.init_params(cfg, jax.random.key(args.seed))
    scfg = ServeConfig(
        max_len=args.prompt_len + args.max_new + 8,
        batch_slots=args.slots,
        temperature=args.temperature,
        max_new_tokens=args.max_new,
        eos_token=-1,  # never stop early in the benchmark
    )
    server = BatchedServer(cfg, params, scfg)
    reqs = [
        Request(prompt=rng.integers(2, cfg.vocab_size, size=args.prompt_len).astype(np.int32))
        for _ in range(args.requests)
    ]
    stats = server.run(reqs)
    print(
        f"[serve] {cfg.name}: {stats['requests']} requests, "
        f"{stats['new_tokens']} new tokens, {stats['tokens_per_s']:,.1f} tok/s"
    )
    return stats


def graph_main(argv=None):
    from repro.core.engine import EngineConfig
    from repro.graph import powerlaw_graph
    from repro.serve.graph_service import (
        GraphQueryRequest,
        GraphService,
        ServiceConfig,
        TenantBudget,
    )

    ap = argparse.ArgumentParser(prog="repro.launch.serve graph")
    ap.add_argument("--vertices", type=int, default=1 << 10)
    ap.add_argument("--deg", type=float, default=6.0)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=2, help="queries per tenant")
    ap.add_argument("--queries", default="q1,q2,q3",
                    help="comma-separated names from PAPER_QUERIES, round-robin")
    ap.add_argument("--max-active", type=int, default=4)
    ap.add_argument("--tick-steps", type=int, default=32)
    ap.add_argument("--match-budget", type=int, default=None,
                    help="per-query match cap (stops queries early)")
    ap.add_argument("--pool-cells", type=int, default=64 << 20)
    args = ap.parse_args(argv)

    graph = powerlaw_graph(args.vertices, args.deg, seed=args.seed)
    svc = GraphService(
        graph,
        ServiceConfig(
            total_queue_cells=args.pool_cells,
            max_active=args.max_active,
            tick_steps=args.tick_steps,
            default_budget=TenantBudget(max_matches=args.match_budget),
        ),
        EngineConfig(batch_size=256),
    )
    names = args.queries.split(",")
    t0 = time.perf_counter()
    tickets = []
    for r in range(args.requests):
        for t in range(args.tenants):
            q = names[(r * args.tenants + t) % len(names)]
            tickets.append(
                svc.submit(GraphQueryRequest(tenant=f"tenant{t}", query=q))
            )
    summary = svc.run_until_idle()
    wall = time.perf_counter() - t0

    lat = [tk.latency_s for tk in tickets if tk.latency_s is not None]
    total = sum(tk.count for tk in tickets)
    print(f"[graph-service] {len(tickets)} requests, {args.tenants} tenants, "
          f"{summary['ticks']} ticks, wall {wall:.2f}s")
    for tk in tickets:
        print(f"  #{tk.id} {tk.request.tenant:>9s} {tk.request.query:>4} "
              f"-> {tk.status:15s} count={tk.count:<8d} "
              f"latency={tk.latency_s:.3f}s wait={tk.queue_wait_s or 0:.3f}s")
    if lat:
        print(f"  p50 {np.percentile(lat, 50):.3f}s  p99 {np.percentile(lat, 99):.3f}s  "
              f"aggregate {total / max(wall, 1e-9):,.0f} matches/s  "
              f"peak pool {svc.peak_pool_cells} cells")
    return tickets


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "graph":
        return graph_main(argv[1:])
    if argv and argv[0] == "lm":
        return lm_main(argv[1:])
    return lm_main(argv)  # backward compatible: bare flags mean LM serving


if __name__ == "__main__":
    main()
