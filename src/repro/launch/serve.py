"""Batched-serving driver: ``python -m repro.launch.serve --arch rwkv6-7b --smoke``."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, smoke_config
from repro.models import transformer as T
from repro.serve.engine import BatchedServer, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = np.random.default_rng(args.seed)
    params = T.init_params(cfg, jax.random.key(args.seed))
    scfg = ServeConfig(
        max_len=args.prompt_len + args.max_new + 8,
        batch_slots=args.slots,
        temperature=args.temperature,
        max_new_tokens=args.max_new,
        eos_token=-1,  # never stop early in the benchmark
    )
    server = BatchedServer(cfg, params, scfg)
    reqs = [
        Request(prompt=rng.integers(2, cfg.vocab_size, size=args.prompt_len).astype(np.int32))
        for _ in range(args.requests)
    ]
    stats = server.run(reqs)
    print(
        f"[serve] {cfg.name}: {stats['requests']} requests, "
        f"{stats['new_tokens']} new tokens, {stats['tokens_per_s']:,.1f} tok/s"
    )
    return stats


if __name__ == "__main__":
    main()
