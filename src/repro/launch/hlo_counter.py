"""Exact static HLO cost counter with while-loop trip multiplication.

XLA's built-in ``cost_analysis`` counts while-loop bodies **once** (verified:
a 10-iteration scanned matmul reports 1 matmul of FLOPs), which silently
undercounts any scanned model by ~num_layers×. This module re-derives the
roofline inputs by walking the compiled HLO text:

  * computations are parsed into per-op records with a local symbol table
    (operand shapes are resolved by name — the printer does not inline them);
  * ``while`` ops multiply their body's counts by the trip count from
    ``backend_config={"known_trip_count":{"n":...}}`` (fallback: the constant
    in the condition computation);
  * ``fusion``/``call``/``conditional`` recurse into their called
    computations (memoised);
  * FLOPs: ``dot`` = 2·batch·M·N·K from the printed dimension numbers
    (convolutions likewise; elementwise ignored — MXU work is what the
    compute roofline bounds);
  * HBM bytes: Σ over materialised ops of (result + operand bytes) — a
    write-once/read-once traffic proxy that matches XLA's own accounting on
    loop-free graphs;
  * collective bytes per kind, using wire-cost conventions: all-gather →
    result bytes, all-reduce → 2× operand (ring), reduce-scatter/all-to-all/
    collective-permute → operand bytes.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shape(text: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Parse the leading (possibly tuple) shape of an op definition; return
    (total bytes, [(dtype, dims), ...])."""
    shapes = []
    total = 0
    # take text up to the op name: shapes appear before the first identifier
    # that is not a shape. Simply scan shape tokens from the front.
    i = 0
    depth_done = False
    head = text
    if text.startswith("("):
        # tuple type: up to matching paren
        depth = 0
        for j, ch in enumerate(text):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    head = text[: j + 1]
                    break
    else:
        head = text.split(" ", 1)[0]
    for m in _SHAPE_RE.finditer(head):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dd = [int(x) for x in dims.split(",") if x.strip()] if dims else []
        n = 1
        for d in dd:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dd))
    return total, shapes


@dataclasses.dataclass
class Counts:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_calls: Dict[str, float] = dataclasses.field(default_factory=dict)
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add_bytes(self, kind: str, n: float):
        self.bytes += n
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + n

    def add(self, other: "Counts", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, v in other.coll_calls.items():
            self.coll_calls[k] = self.coll_calls.get(k, 0.0) + v * mult
        for k, v in other.by_kind.items():
            self.by_kind[k] = self.by_kind.get(k, 0.0) + v * mult

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _dot_flops(attrs: str, lhs_shape, rhs_shape, result_elems: float) -> float:
    def dims(key):
        m = re.search(key + r"=\{([0-9,]*)\}", attrs)
        return [int(x) for x in m.group(1).split(",") if x.strip()] if m else []

    lc = dims("lhs_contracting_dims")
    lb = dims("lhs_batch_dims")
    if lhs_shape is None:
        return 2.0 * result_elems  # fallback
    k = 1
    for d in lc:
        k *= lhs_shape[1][d] if d < len(lhs_shape[1]) else 1
    return 2.0 * result_elems * k


class HloCounter:
    def __init__(self, text: str):
        self.computations = self._split(text)
        self._memo: Dict[str, Counts] = {}

    @staticmethod
    def _split(text: str) -> Dict[str, List[str]]:
        comps: Dict[str, List[str]] = {}
        cur_name, cur_lines = None, []
        for line in text.splitlines():
            s = line.strip()
            # computation header: [ENTRY] %name (args...) -> result { — args may
            # contain nested parens (tuple types), so match the name prefix only.
            if s.endswith("{") and "->" in s and (s.startswith("%") or s.startswith("ENTRY")):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
                if m:
                    cur_name = m.group(1)
                    cur_lines = []
                    comps[cur_name] = cur_lines
                    if s.startswith("ENTRY"):
                        comps["__entry__"] = cur_lines
                    continue
            if s == "}":
                cur_name = None
                continue
            if cur_name is not None:
                cur_lines.append(s)
        return comps

    def _dus_update_bytes(self, comp_name: str) -> Optional[int]:
        """Exact update-operand size of a dynamic-update-slice inside a fused
        computation (the real traffic of an in-place stack write)."""
        lines = self.computations.get(comp_name, [])
        symtab: Dict[str, int] = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            nbytes, _ = _parse_shape(m.group(2))
            symtab[m.group(1)] = nbytes
        for line in lines:
            if "dynamic-update-slice(" in line:
                p0 = line.find("dynamic-update-slice(")
                ops = _OPND_RE.findall(line[p0:])
                if len(ops) >= 2:
                    return symtab.get(ops[1], None)
        return None

    def _root_kind(self, comp_name: str) -> str:
        """Op kind of a computation's ROOT instruction."""
        for line in self.computations.get(comp_name, []):
            if line.startswith("ROOT"):
                m = _DEF_RE.match(line)
                if not m:
                    return ""
                rest = m.group(2)
                after = rest
                if rest.startswith("("):
                    depth = 0
                    for j, ch in enumerate(rest):
                        if ch == "(":
                            depth += 1
                        elif ch == ")":
                            depth -= 1
                            if depth == 0:
                                after = rest[j + 1:]
                                break
                else:
                    after = rest.split(" ", 1)[1] if " " in rest else ""
                km = re.match(r"\s*([\w\-]+)", after)
                return km.group(1) if km else ""
        return ""

    def count(self, name: str = "__entry__") -> Counts:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Counts()  # cycle guard
        lines = self.computations.get(name, [])
        total = Counts()
        symtab: Dict[str, Tuple[int, list]] = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            opname, rest = m.group(1), m.group(2)
            nbytes, shapes = _parse_shape(rest)
            symtab[opname] = (nbytes, shapes)
            # op kind = first identifier after the shape spec
            after = rest
            if rest.startswith("("):
                depth = 0
                for j, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            after = rest[j + 1:]
                            break
            else:
                after = rest.split(" ", 1)[1] if " " in rest else ""
            after = after.strip()
            km = re.match(r"([\w\-]+)", after)
            kind = km.group(1) if km else ""
            base_kind = re.sub(r"-(start|done|update)$", "", kind)

            # operand names: inside the first paren group after the kind
            p0 = after.find("(")
            operands: List[str] = []
            if p0 >= 0:
                depth = 0
                for j in range(p0, len(after)):
                    if after[j] == "(":
                        depth += 1
                    elif after[j] == ")":
                        depth -= 1
                        if depth == 0:
                            operands = _OPND_RE.findall(after[p0 : j + 1])
                            break
            opnd_bytes = sum(symtab.get(o, (0, []))[0] for o in operands)

            if kind == "while":
                cb = _COND_BODY_RE.search(after)
                trips = 1
                tm = _TRIP_RE.search(after)
                if tm:
                    trips = int(tm.group(1))
                elif cb:
                    cond_lines = self.computations.get(cb.group(1), [])
                    for cl in cond_lines:
                        c = re.search(r"constant\((\d+)\)", cl)
                        if c:
                            trips = int(c.group(1))
                if cb:
                    total.add(self.count(cb.group(2)), mult=trips)
                continue
            if kind == "conditional":
                bm = _BRANCHES_RE.search(after)
                if bm:
                    subs = _OPND_RE.findall(bm.group(1))
                    for sname in subs:
                        total.add(self.count(sname), mult=1.0 / max(1, len(subs)))
                continue
            called = _CALLS_RE.search(after) or _TO_APPLY_RE.search(after)
            if kind in ("fusion", "call") and called:
                cname = called.group(1)
                inner = self.count(cname)
                total.flops += inner.flops
                root_kind = self._root_kind(cname)
                opnd_sizes = [symtab.get(o, (0, []))[0] for o in operands]
                if root_kind == "dynamic-update-slice" or "dynamic-update-slice" in opname:
                    # In-place stack write: traffic = update read + write, not
                    # the full buffer the fusion nominally returns.
                    update = self._dus_update_bytes(cname)
                    if update is None:
                        update = sum(opnd_sizes) - (max(opnd_sizes) if opnd_sizes else 0)
                    total.add_bytes("fusion-dus", 2 * update)
                elif root_kind in ("dynamic-slice", "slice", "gather") or "dynamic-slice" in opname:
                    total.add_bytes("fusion-slice", 2 * nbytes)
                else:
                    # Fused internals stay on-chip: traffic = operands + result.
                    # Operands the fusion only slices from (stacked params per
                    # scan trip) are capped at the fusion's own result size.
                    capped = sum(min(s, max(nbytes, 1)) for s in opnd_sizes)
                    total.add_bytes("fusion", nbytes + capped)
                for k, v in inner.coll.items():
                    total.coll[k] = total.coll.get(k, 0.0) + v
                continue
            if base_kind in _COLLECTIVES:
                if kind.endswith("-done"):
                    continue  # counted at -start
                if base_kind == "all-gather":
                    wire = nbytes
                elif base_kind == "all-reduce":
                    wire = 2 * opnd_bytes
                else:
                    wire = opnd_bytes
                total.coll[base_kind] = total.coll.get(base_kind, 0.0) + wire
                total.coll_calls[base_kind] = total.coll_calls.get(base_kind, 0.0) + 1
                total.add_bytes(base_kind, nbytes + opnd_bytes)
                continue
            if kind in _SKIP_OPS or not kind:
                continue
            if kind in ("dynamic-slice", "slice", "gather"):
                total.add_bytes(kind, 2 * nbytes)  # read slice + write result
                continue
            if kind == "dynamic-update-slice":
                upd = symtab.get(operands[1], (0, []))[0] if len(operands) > 1 else nbytes
                total.add_bytes(kind, 2 * upd)     # in-place: read + write update
                continue
            if kind == "scatter":
                upd = symtab.get(operands[-1], (0, []))[0] if operands else nbytes
                total.add_bytes(kind, 2 * upd)
                continue
            if kind in ("broadcast", "reshape", "transpose", "copy", "convert", "reduce"):
                total.add_bytes(kind, nbytes + min(opnd_bytes, 4 * max(nbytes, 1)))
                continue
            if kind in ("dot", "convolution"):
                lhs = symtab.get(operands[0]) if operands else None
                res_elems = 0
                _, rshapes = symtab[opname]
                for dt, dd in rshapes:
                    n = 1
                    for d in dd:
                        n *= d
                    res_elems += n
                total.flops += _dot_flops(
                    after, (lhs[1][0][0], lhs[1][0][1]) if lhs and lhs[1] else None, None, res_elems
                )
                total.add_bytes("dot", nbytes + opnd_bytes)
                continue
            # generic materialised op
            total.add_bytes(kind, nbytes + opnd_bytes)
        self._memo[name] = total
        return total


def analyze(hlo_text: str) -> Counts:
    return HloCounter(hlo_text).count()
