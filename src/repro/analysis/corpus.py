"""Clean-tree flowcheck corpus: every paper query under every plan space,
plus each query's merged delta-flow decomposition (DESIGN.md §Delta-plans).

This is what ``python -m repro.analysis --flowcheck`` (and the flowcheck
stamp in ``benchmarks.common.record_bench``) verifies: the optimiser and
translator must produce plans/dataflows the static verifier accepts, for the
whole Table-2 plan-space matrix, with queue plans that fit the default
service pool. Planning is done against synthetic power-law statistics
(``GraphStats.synthetic``) so the corpus needs no data graph and stays fast
(pure Python, no device work).
"""
from __future__ import annotations

import functools
from typing import List, Tuple

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.flowcheck import check_flow, check_plan
from repro.core.cost import GraphStats
from repro.core.dataflow import delta_flows, merge_flows, translate
from repro.core.optimizer import optimal_plan
from repro.core.plan import PLAN_SPACES
from repro.core.query import PAPER_QUERIES

# Nominal sizing for the queue-cell accounting leg: a mid-size power-law
# graph and the default single-query engine configuration.
_CORPUS_VERTICES = 1 << 11
_CORPUS_AVG_DEG = 6.0
_CORPUS_D_PAD = 64
_CORPUS_MACHINES = 8


def corpus_cases() -> List[Tuple[str, str]]:
    return [(q, s) for q in PAPER_QUERIES for s in PLAN_SPACES]


@functools.lru_cache(maxsize=1)
def _corpus_findings_cached() -> Tuple[Diagnostic, ...]:
    from repro.core.engine import EngineConfig
    from repro.serve.graph_service import ServiceConfig

    stats = GraphStats.synthetic(_CORPUS_VERTICES, _CORPUS_AVG_DEG)
    cfg = EngineConfig()
    pool = ServiceConfig().total_queue_cells
    out: List[Diagnostic] = []
    for qname, space in corpus_cases():
        where = f"corpus::{qname}/{space}"
        try:
            plan = optimal_plan(PAPER_QUERIES[qname], stats, _CORPUS_MACHINES, space)
        except Exception as e:  # noqa: BLE001 — a planner crash is a finding
            out.append(Diagnostic(
                "plan-failure", f"optimiser failed: {type(e).__name__}: {e}",
                where=where,
            ))
            continue
        for d in check_plan(plan):
            out.append(Diagnostic(d.rule, d.message, d.severity,
                                  where=f"{where}/{d.where or 'plan'}",
                                  hint=d.hint))
        try:
            flow = translate(plan)
        except Exception as e:  # noqa: BLE001
            out.append(Diagnostic(
                "translate-failure",
                f"translation failed: {type(e).__name__}: {e}", where=where,
            ))
            continue
        for d in check_flow(flow, cfg=cfg, d_pad=_CORPUS_D_PAD, max_cells=pool):
            out.append(Diagnostic(d.rule, d.message, d.severity,
                                  where=f"{where}/op[{d.op_index}]", hint=d.hint))
    # Delta leg: the merged k-sink delta decomposition of each paper query
    # (the flow a standing query re-runs per batch) must also verify clean —
    # epochs, schemas, and queue pricing alike. The flows are batch-
    # independent, so one plan per query suffices.
    for qname in PAPER_QUERIES:
        where = f"corpus::{qname}/delta"
        try:
            plan = optimal_plan(PAPER_QUERIES[qname], stats, _CORPUS_MACHINES,
                                "huge")
            merged, _ = merge_flows(delta_flows(plan))
        except Exception as e:  # noqa: BLE001
            out.append(Diagnostic(
                "translate-failure",
                f"delta decomposition failed: {type(e).__name__}: {e}",
                where=where,
            ))
            continue
        for d in check_flow(merged, cfg=cfg, d_pad=_CORPUS_D_PAD, max_cells=pool):
            out.append(Diagnostic(d.rule, d.message, d.severity,
                                  where=f"{where}/op[{d.op_index}]", hint=d.hint))
    return tuple(out)


def corpus_findings() -> List[Diagnostic]:
    return list(_corpus_findings_cached())
