"""AST-based tracer-safety lint over the source tree.

Rules (DESIGN.md §Static-analysis):

``host-sync``
    ``.item()`` / ``.tolist()`` / ``int()`` / ``float()`` / ``bool()`` /
    ``np.asarray()`` / ``np.array()`` / ``jax.device_get()`` applied to a
    *traced* value inside a jitted / shard_map'd / vmapped function — a
    device→host sync in a hot path (and a trace error for data-dependent
    values).

``traced-branch``
    ``if`` / ``while`` / ``assert`` whose condition references a traced value
    inside a traced function — Python control flow cannot branch on tracers;
    use ``jnp.where`` / ``lax.cond`` / ``lax.while_loop``.

``queue-dtype``
    An ``INVALID``-filled buffer (``jnp.full(..., INVALID)`` et al. assigned
    to a ``*buf*``/``*queue*`` name) created without an explicit int32 dtype —
    dtype drift into the ``[P, CAP, K]`` device queues silently widens every
    shuffle and breaks the int32 key packing (``machine·|V|+vid`` must fit
    int32).

``kernel-ref-missing`` / ``kernel-test-missing``
    A public Pallas kernel ``X_kernel`` in ``kernels/<name>/<name>.py``
    without a pure-jnp twin ``X_ref`` in the sibling ``ref.py``, or not
    referenced by the parity suite ``tests/test_kernels.py`` — the
    differential-testing contract every kernel must keep.

Tracedness is detected statically: a function is *traced* when it is
decorated with (or wrapped by) ``jit`` / ``pjit`` / ``vmap`` / ``pmap`` /
``shard_map`` / ``pallas_call`` (including ``functools.partial(jax.jit, …)``
decorators and local functions passed by name to such a wrapper, e.g. the
``f`` handed to ``self._shardmap``), or nested inside a traced function.
Within a traced function, *traced values* are approximated by forward taint:
parameters are tainted, and any name assigned from an expression touching a
tainted name becomes tainted. Closure variables (e.g. config flags captured
from the enclosing builder) stay untainted, so static-shape branching is not
flagged.

Finding locations are ``relpath::qualname::symbol`` — no line numbers — so
baseline entries survive unrelated edits.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.diagnostics import Diagnostic, ERROR

TRACE_WRAPPERS = {
    "jit", "pjit", "vmap", "pmap", "shard_map", "_shardmap", "pallas_call",
}
HOST_SYNC_CALLS = {"int", "float", "bool"}
HOST_SYNC_ATTRS = {"item", "tolist"}
HOST_SYNC_QUALIFIED = {("np", "asarray"), ("np", "array"), ("numpy", "asarray"),
                       ("numpy", "array"), ("jax", "device_get")}
BUFFER_FILLS = {"full", "zeros", "ones", "empty"}
# Attributes of traced arrays that are *static* at trace time: branching on
# them is ordinary Python metaprogramming, not data-dependent control flow.
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "weak_type"}


def _terminal(node: ast.AST) -> Optional[str]:
    """Right-most name of a Name / Attribute / Call-func chain."""
    if isinstance(node, ast.Call):
        return _terminal(node.func)
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _dotted(node: ast.AST) -> Tuple[str, ...]:
    """(base, attr, …) for Name/Attribute chains, () when not a plain chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _is_trace_wrapper(node: ast.AST) -> bool:
    t = _terminal(node)
    if t in TRACE_WRAPPERS:
        return True
    # functools.partial(jax.jit, static_argnames=...) used as a decorator
    if isinstance(node, ast.Call) and _terminal(node.func) == "partial":
        return any(_terminal(a) in TRACE_WRAPPERS for a in node.args)
    return False


# ---------------------------------------------------------------------------
# Traced-function discovery
# ---------------------------------------------------------------------------

def _static_argnames(call: ast.Call, fn: ast.AST) -> Set[str]:
    """Parameter names declared static via ``static_argnames``/``static_argnums``
    in a jit-style wrapper call — those arrive as plain Python values, not
    tracers, so they must not seed the taint set."""
    names: Set[str] = set()
    a = fn.args
    positional = [p.arg for p in a.posonlyargs + a.args]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    if 0 <= n.value < len(positional):
                        names.add(positional[n.value])
    return names


def _collect_traced(tree: ast.Module) -> Dict[ast.AST, Set[str]]:
    """FunctionDefs that are traced — decorator-wrapped, or passed by name to a
    trace wrapper call anywhere in the module — mapped to their statically
    declared (non-tracer) parameter names."""
    traced: Dict[ast.AST, Set[str]] = {}
    defs_by_name: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(node.name, []).append(node)
            for dec in node.decorator_list:
                if _is_trace_wrapper(dec):
                    static = traced.setdefault(node, set())
                    if isinstance(dec, ast.Call):
                        static |= _static_argnames(dec, node)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_trace_wrapper(node.func):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    for fn in defs_by_name.get(arg.id, []):
                        traced.setdefault(fn, set()).update(
                            _static_argnames(node, fn))
    return traced


def _qualnames(tree: ast.Module) -> Dict[ast.AST, str]:
    """Map every FunctionDef to its dotted qualname (Class.method, fn.inner)."""
    out: Dict[ast.AST, str] = {}

    def walk(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                out[child] = q
                walk(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                walk(child, f"{prefix}{child.name}.")
            else:
                walk(child, prefix)

    walk(tree, "")
    return out


# ---------------------------------------------------------------------------
# Taint lint inside one traced function
# ---------------------------------------------------------------------------

class _FnLinter:
    def __init__(self, fn: ast.AST, relpath: str, qualname: str,
                 static_params: Optional[Set[str]] = None):
        self.fn = fn
        self.relpath = relpath
        self.qualname = qualname
        self.findings: List[Diagnostic] = []
        a = fn.args
        self.tainted: Set[str] = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
        if a.vararg:
            self.tainted.add(a.vararg.arg)
        if a.kwarg:
            self.tainted.add(a.kwarg.arg)
        self.tainted -= static_params or set()

    def _emit(self, rule: str, symbol: str, message: str, hint: str) -> None:
        d = Diagnostic(
            rule=rule, message=message, severity=ERROR, hint=hint,
            where=f"{self.relpath}::{self.qualname}::{symbol}",
        )
        if d.key() not in {f.key() for f in self.findings}:
            self.findings.append(d)

    def _expr_tainted(self, node: ast.AST) -> bool:
        # Taint does not flow through trace-time-static projections: an
        # array's .shape/.ndim/.dtype (and len() of it) are plain Python
        # values while tracing, so `if x.shape[0] % TILE:` is legal.
        if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
            return False
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "len"):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        return any(self._expr_tainted(c) for c in ast.iter_child_nodes(node))

    def _taint_target(self, target: ast.AST) -> None:
        for n in ast.walk(target):
            if isinstance(n, ast.Name):
                self.tainted.add(n.id)

    def _propagate(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, ast.Assign) and self._expr_tainted(stmt.value):
                for t in stmt.targets:
                    self._taint_target(t)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                if stmt.value is not None and self._expr_tainted(stmt.value):
                    self._taint_target(stmt.target)
            elif isinstance(stmt, ast.For):
                if self._expr_tainted(stmt.iter):
                    self._taint_target(stmt.target)
                self._propagate(stmt.body + stmt.orelse)
            elif isinstance(stmt, (ast.If, ast.While)):
                self._propagate(stmt.body + stmt.orelse)
            elif isinstance(stmt, (ast.With,)):
                self._propagate(stmt.body)
            elif isinstance(stmt, ast.Try):
                self._propagate(stmt.body + stmt.orelse + stmt.finalbody)
                for h in stmt.handlers:
                    self._propagate(h.body)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue  # nested defs are linted as their own traced scope

    def _check_calls(self, root: ast.AST) -> None:
        for node in ast.walk(root):
            if not isinstance(node, ast.Call):
                continue
            args = list(node.args) + [kw.value for kw in node.keywords]
            arg_tainted = any(self._expr_tainted(a) for a in args)
            func = node.func
            if isinstance(func, ast.Name) and func.id in HOST_SYNC_CALLS and arg_tainted:
                self._emit(
                    "host-sync", func.id,
                    f"{func.id}() on a traced value inside a traced function",
                    "keep it on device (jnp) or hoist the sync out of the "
                    "jitted/shard_map'd region",
                )
            elif isinstance(func, ast.Attribute):
                if func.attr in HOST_SYNC_ATTRS and self._expr_tainted(func.value):
                    self._emit(
                        "host-sync", func.attr,
                        f".{func.attr}() on a traced value inside a traced function",
                        "return the array and sync at the call site",
                    )
                elif _dotted(func)[:2] in HOST_SYNC_QUALIFIED and arg_tainted:
                    self._emit(
                        "host-sync", ".".join(_dotted(func)[:2]),
                        f"{'.'.join(_dotted(func)[:2])}() forces a host copy of a "
                        "traced value",
                        "stay in jnp inside traced code",
                    )

    def _check_branches(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(stmt, (ast.If, ast.While)) and self._expr_tainted(stmt.test):
                kw = "if" if isinstance(stmt, ast.If) else "while"
                self._emit(
                    "traced-branch", kw,
                    f"`{kw}` on a traced value — Python control flow cannot "
                    "branch on tracers",
                    "use jnp.where / lax.cond / lax.while_loop",
                )
            if isinstance(stmt, ast.Assert) and self._expr_tainted(stmt.test):
                self._emit(
                    "traced-branch", "assert",
                    "`assert` on a traced value — either a trace error or a "
                    "silent no-op under jit",
                    "use checkify or validate outside the traced region",
                )
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field, None)
                if sub:
                    self._check_branches(sub)
            for h in getattr(stmt, "handlers", []):
                self._check_branches(h.body)

    def run(self) -> List[Diagnostic]:
        # Two propagation sweeps approximate a fixpoint for loop-carried taint.
        self._propagate(self.fn.body)
        self._propagate(self.fn.body)
        self._check_branches(self.fn.body)
        self._check_calls(self.fn)
        return self.findings


# ---------------------------------------------------------------------------
# Queue-buffer dtype rule (module-wide)
# ---------------------------------------------------------------------------

def _lint_queue_dtypes(
    tree: ast.Module, relpath: str, quals: Dict[ast.AST, str]
) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    # Map each statement to its enclosing function qualname for the location.
    owner: Dict[ast.AST, str] = {}

    def tag(node: ast.AST, q: str) -> None:
        for child in ast.iter_child_nodes(node):
            nq = quals.get(child, q)
            owner[child] = nq
            tag(child, nq)

    tag(tree, "<module>")

    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        call = node.value
        if not (isinstance(call, ast.Call) and _terminal(call.func) in BUFFER_FILLS):
            continue
        targets = [t for t in node.targets]
        names = [n for t in targets for n in ast.walk(t) if isinstance(n, (ast.Name, ast.Attribute))]
        tnames = {(_terminal(n) or "").lower() for n in names}
        is_queueish = any("buf" in t or "queue" in t for t in tnames)
        fills_invalid = any(
            _terminal(a) == "INVALID" for a in call.args
        ) or any(_terminal(kw.value) == "INVALID" for kw in call.keywords
                 if kw.arg in (None, "fill_value"))
        if not (is_queueish and fills_invalid):
            continue
        dtype_node = None
        for kw in call.keywords:
            if kw.arg == "dtype":
                dtype_node = kw.value
        if dtype_node is None and _terminal(call.func) == "full" and len(call.args) >= 3:
            dtype_node = call.args[2]
        tname = sorted(tnames)[0] if tnames else "buf"
        q = owner.get(node, "<module>")
        if dtype_node is None:
            out.append(Diagnostic(
                "queue-dtype",
                f"INVALID-filled buffer {tname!r} created without an explicit "
                "dtype; queue buffers are int32 by contract ([P, CAP, K] "
                "shape convention)",
                where=f"{relpath}::{q}::{tname}",
                hint="pass jnp.int32 explicitly",
            ))
        elif _terminal(dtype_node) != "int32":
            out.append(Diagnostic(
                "queue-dtype",
                f"INVALID-filled buffer {tname!r} created with dtype "
                f"{_terminal(dtype_node)!r}; queue buffers are int32 by contract",
                where=f"{relpath}::{q}::{tname}",
                hint="use jnp.int32 (keys pack machine·|V|+vid into int32)",
            ))
    return out


# ---------------------------------------------------------------------------
# Kernel ref-twin / parity-test rule
# ---------------------------------------------------------------------------

def _public_kernels(path: str) -> List[str]:
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    return [
        n.name
        for n in tree.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        and n.name.endswith("_kernel")
        and not n.name.startswith("_")
    ]


def check_kernel_twins(
    kernels_dir: str, tests_file: Optional[str], rel_prefix: str = "kernels"
) -> List[Diagnostic]:
    """Every public ``X_kernel`` in ``kernels/<name>/<name>.py`` needs an
    ``X_ref`` twin in the sibling ``ref.py`` and a mention in the parity
    suite (``tests/test_kernels.py``)."""
    out: List[Diagnostic] = []
    test_text = ""
    if tests_file and os.path.exists(tests_file):
        with open(tests_file, encoding="utf-8") as f:
            test_text = f.read()
    for name in sorted(os.listdir(kernels_dir)):
        pkg = os.path.join(kernels_dir, name)
        main = os.path.join(pkg, f"{name}.py")
        if not os.path.isdir(pkg) or not os.path.exists(main):
            continue
        ref_path = os.path.join(pkg, "ref.py")
        ref_names: Set[str] = set()
        if os.path.exists(ref_path):
            with open(ref_path, encoding="utf-8") as f:
                ref_tree = ast.parse(f.read(), filename=ref_path)
            ref_names = {
                n.name for n in ref_tree.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
        for kernel in _public_kernels(main):
            stem = kernel[: -len("_kernel")]
            rel = f"{rel_prefix}/{name}/{name}.py"
            if f"{stem}_ref" not in ref_names:
                out.append(Diagnostic(
                    "kernel-ref-missing",
                    f"Pallas kernel {kernel} has no pure-jnp twin "
                    f"{stem}_ref in {name}/ref.py",
                    where=f"{rel}::{kernel}::ref",
                    hint="add the ref twin; the differential harness needs it",
                ))
            if test_text and kernel not in test_text:
                out.append(Diagnostic(
                    "kernel-test-missing",
                    f"Pallas kernel {kernel} is not referenced by the parity "
                    "suite tests/test_kernels.py",
                    where=f"{rel}::{kernel}::test",
                    hint="add an interpret=True kernel-vs-ref parity test",
                ))
    return out


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def lint_source(src: str, relpath: str) -> List[Diagnostic]:
    """Lint one module's source text (host-sync / traced-branch / queue-dtype)."""
    tree = ast.parse(src, filename=relpath)
    traced = _collect_traced(tree)
    quals = _qualnames(tree)
    out: List[Diagnostic] = []
    # Nested defs inside traced functions are traced too (with no static
    # params of their own — their closure variables stay untainted anyway).
    closure: Dict[ast.AST, Set[str]] = {}
    for fn, static in traced.items():
        closure.setdefault(fn, set()).update(static)
        for sub in ast.walk(fn):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                closure.setdefault(sub, set())
    for fn in sorted(closure, key=lambda n: (n.lineno, quals.get(n, ""))):
        out.extend(_FnLinter(fn, relpath, quals.get(fn, fn.name),
                             closure[fn]).run())
    out.extend(_lint_queue_dtypes(tree, relpath, quals))
    return out


def lint_file(path: str, root: str) -> List[Diagnostic]:
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), rel)


def lint_tree(root: str, tests_file: Optional[str] = None) -> List[Diagnostic]:
    """Lint every ``*.py`` under ``root`` (the ``src/repro`` package) plus the
    kernel ref-twin contract."""
    out: List[Diagnostic] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fname in sorted(filenames):
            if fname.endswith(".py"):
                out.extend(lint_file(os.path.join(dirpath, fname), root))
    kernels_dir = os.path.join(root, "kernels")
    if os.path.isdir(kernels_dir):
        out.extend(check_kernel_twins(kernels_dir, tests_file))
    return out
