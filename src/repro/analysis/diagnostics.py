"""Structured findings shared by flowcheck and tracelint.

A :class:`Diagnostic` is the unit both passes emit and every consumer —
engine pre-flight, service admission, the CLI, CI — agrees on: a stable rule
id, a severity, a location (op index for plan/dataflow findings, a
``path::qualname::symbol`` triple for source findings), a human message, and
a fix hint. Locations deliberately exclude line numbers so baseline entries
survive unrelated edits.

Baseline file format (``analysis/baseline.txt``), one finding key per line::

    rule|where        # one-line justification (required)

Lines starting with ``#`` and blank lines are ignored. ``split_baselined``
partitions findings into (new, suppressed); only *new* error-severity
findings fail a run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    rule: str                       # stable rule id, e.g. "join-key-incompatible"
    message: str
    severity: str = ERROR           # "error" | "warning"
    where: str = ""                 # source findings: "path::qualname::symbol"
    op_index: Optional[int] = None  # plan/dataflow findings: offending op
    hint: str = ""                  # how to fix it

    def key(self) -> str:
        """Stable identity used for baseline matching (no line numbers)."""
        loc = self.where if self.where else (
            f"op[{self.op_index}]" if self.op_index is not None else "-"
        )
        return f"{self.rule}|{loc}"

    def format(self) -> str:
        loc = self.where or (
            f"op[{self.op_index}]" if self.op_index is not None else ""
        )
        parts = [f"{self.severity}: {self.rule}"]
        if loc:
            parts.append(f"[{loc}]")
        parts.append(self.message)
        if self.hint:
            parts.append(f"(fix: {self.hint})")
        return " ".join(parts)


class FlowcheckError(ValueError):
    """Raised by the mandatory engine/service pre-flight when a plan or
    dataflow fails static verification. Carries the structured diagnostics so
    callers (e.g. ``GraphService`` admission) can reject with the rule ids
    instead of a stringly-typed error."""

    def __init__(self, diagnostics: Sequence[Diagnostic]):
        self.diagnostics: Tuple[Diagnostic, ...] = tuple(diagnostics)
        super().__init__(
            "flowcheck failed: "
            + "; ".join(d.format() for d in self.diagnostics)
        )


def errors(diags: Iterable[Diagnostic]) -> List[Diagnostic]:
    return [d for d in diags if d.severity == ERROR]


def format_diagnostics(diags: Iterable[Diagnostic]) -> str:
    return "\n".join(d.format() for d in diags)


def load_baseline(path: str) -> Dict[str, str]:
    """Parse a baseline file into ``{finding_key: justification}``. Every
    entry must carry a justification comment — an unjustified suppression is
    itself rejected (the baseline is a reviewed artifact, not a mute list)."""
    entries: Dict[str, str] = {}
    with open(path, encoding="utf-8") as f:
        for lineno, raw in enumerate(f, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            key, sep, why = line.partition("#")
            key = key.strip()
            why = why.strip()
            if "|" not in key:
                raise ValueError(
                    f"{path}:{lineno}: malformed baseline key {key!r} "
                    "(expected 'rule|where  # justification')"
                )
            if not sep or not why:
                raise ValueError(
                    f"{path}:{lineno}: baseline entry {key!r} lacks a "
                    "justification comment"
                )
            entries[key] = why
    return entries


def split_baselined(
    diags: Sequence[Diagnostic], baseline: Dict[str, str]
) -> Tuple[List[Diagnostic], List[Diagnostic]]:
    """Partition findings into ``(new, suppressed)`` by baseline key."""
    new: List[Diagnostic] = []
    suppressed: List[Diagnostic] = []
    for d in diags:
        (suppressed if d.key() in baseline else new).append(d)
    return new, suppressed
