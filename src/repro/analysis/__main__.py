"""CLI for the static analyses (DESIGN.md §Static-analysis).

    PYTHONPATH=src python -m repro.analysis --all --baseline analysis/baseline.txt

Passes:
  --flowcheck   verify every paper query × plan space (optimiser → plan →
                dataflow → queue-cell accounting), no execution
  --tracelint   AST lint of src/repro for tracer-unsafe Python, queue dtype
                drift, and missing kernel ref twins / parity tests
  --all         both (the default when no pass is selected)

  --baseline F  suppress findings whose keys appear in F (checked-in,
                justified); only *new* error findings fail the run
  --fixture N   run one seeded known-bad fixture instead (exits nonzero with
                its rule ids; N=list prints the fixture names)
  --list-rules  print the rule catalogue and exit

Exit status: 0 when no new error-severity findings, 1 otherwise, 2 on usage
errors.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List

from repro.analysis.diagnostics import (
    Diagnostic,
    format_diagnostics,
    load_baseline,
    split_baselined,
)

RULES = {
    # flowcheck — dataflow
    "dag-order": "op inputs must precede the op (topological emission order)",
    "dag-cycle": "op is its own ancestor; a join barrier over it deadlocks",
    "op-kind": "unknown operator kind",
    "op-arity": "wrong number of inputs for the operator kind",
    "no-sink": "dataflow lacks a sink operator",
    "sink-consumed": "an op reads a sink's output",
    "orphan-op": "op never reaches a sink; its results are silently dropped",
    "schema-scan": "scan schema does not match its edge",
    "schema-extend": "extend schema is not input schema + new vertex",
    "schema-verify": "verify must preserve its input schema / verify_pos bounds",
    "schema-dup": "schema matches a query vertex twice (injectivity broken)",
    "ext-disconnected": "extend/verify with empty Eq.-2 set (cross product)",
    "ext-bounds": "ext position outside the input schema",
    "filter-bounds": "lt/gt order-filter column does not exist",
    "join-key-empty": "join with an empty key (cross product)",
    "join-key-incompatible": "join key binds different query vertices per side",
    "join-schema": "join output schema is not left + right_extra",
    "join-cross-bounds": "cross filter indexes outside the output schema",
    "comm-illegal": "op comm mode illegal per Eq. 3 (§5.2 rewrites pull joins)",
    "epoch-illegal": "bad scan_epoch/ext_epochs tag, or epoch on the wrong op kind",
    "epoch-no-delta-scan": "'old'-epoch probe without a delta-seeded ancestor scan",
    "queue-over-pool": "queue plan exceeds the Theorem-5.4 / slot-pool budget",
    # flowcheck — plan/query
    "query-empty": "query has no edges",
    "query-vertex-gap": "query vertex numbering is not dense",
    "query-disconnected": "query graph is disconnected",
    "query-self-loop": "query has a self loop",
    "plan-cover": "plan root does not cover the query's edge set",
    "plan-empty-node": "plan node with an empty sub-query",
    "subquery-disconnected": "plan node's sub-query is disconnected",
    "join-children": "join node's children do not partition its edges",
    "eq3-illegal": "join (algo, comm) not legal per Eq. 3",
    "symmetry-unknown": "symmetry condition references unknown vertices",
    "plan-failure": "optimiser crashed on a corpus case",
    "translate-failure": "plan translation crashed on a corpus case",
    # tracelint
    "host-sync": "device→host sync inside a traced function",
    "traced-branch": "Python if/while/assert on a traced value",
    "queue-dtype": "non-int32 dtype flowing into an INVALID-filled queue buffer",
    "kernel-ref-missing": "Pallas kernel lacks its pure-jnp ref twin",
    "kernel-test-missing": "Pallas kernel not covered by tests/test_kernels.py",
}


def _src_root() -> str:
    import repro

    return os.path.dirname(os.path.abspath(repro.__file__))


def _tests_file() -> str:
    repo = os.path.dirname(os.path.dirname(_src_root()))
    return os.path.join(repo, "tests", "test_kernels.py")


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--flowcheck", action="store_true")
    ap.add_argument("--tracelint", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--baseline", metavar="FILE", default=None)
    ap.add_argument("--root", metavar="DIR", default=None,
                    help="source tree to lint (default: the repro package)")
    ap.add_argument("--fixture", metavar="NAME", default=None)
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule:24s} {desc}")
        return 0

    if args.fixture is not None:
        from repro.analysis.fixtures import FIXTURES, run_fixture

        if args.fixture == "list" or args.fixture not in FIXTURES:
            print("fixtures:", ", ".join(sorted(FIXTURES)))
            return 0 if args.fixture == "list" else 2
        diags, expected = run_fixture(args.fixture)
        print(format_diagnostics(diags))
        fired = {d.rule for d in diags}
        missing = [r for r in expected if r not in fired]
        if missing:
            print(f"FIXTURE BROKEN: expected rule(s) {missing} did not fire")
            return 2
        print(f"fixture {args.fixture!r}: expected rule(s) "
              f"{list(expected)} fired")
        return 1  # a fixture run is *supposed* to find problems

    run_flow = args.flowcheck or args.all or not (args.flowcheck or args.tracelint)
    run_lint = args.tracelint or args.all or not (args.flowcheck or args.tracelint)

    findings: List[Diagnostic] = []
    if run_flow:
        from repro.analysis.corpus import corpus_cases, corpus_findings

        flow_findings = corpus_findings()
        findings.extend(flow_findings)
        print(f"flowcheck: {len(corpus_cases())} query×space cases, "
              f"{len(flow_findings)} finding(s)")
    if run_lint:
        from repro.analysis.tracelint import lint_tree

        root = args.root or _src_root()
        lint_findings = lint_tree(root, _tests_file())
        findings.extend(lint_findings)
        print(f"tracelint: scanned {root}, {len(lint_findings)} finding(s)")

    baseline = {}
    if args.baseline:
        baseline = load_baseline(args.baseline)
    new, suppressed = split_baselined(findings, baseline)
    if suppressed:
        print(f"baseline: suppressed {len(suppressed)} known finding(s)")
    stale = sorted(set(baseline) - {d.key() for d in findings})
    if stale:
        print(f"baseline: {len(stale)} stale entr(y/ies) no longer firing "
              f"(prune them): {', '.join(stale)}")
    new_errors = [d for d in new if d.severity == "error"]
    if new:
        print(format_diagnostics(new))
    print(f"result: {len(new_errors)} new error(s), "
          f"{len(new) - len(new_errors)} new warning(s)")
    return 1 if new_errors else 0


if __name__ == "__main__":
    sys.exit(main())
