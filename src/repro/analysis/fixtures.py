"""Seeded known-bad fixtures for flowcheck / tracelint.

Each fixture is a deliberately malformed plan, dataflow, or source snippet
with the rule id(s) the analyses must report. They serve three consumers:

* ``tests/test_flowcheck.py`` / ``tests/test_tracelint.py`` assert the
  expected rule ids fire;
* ``python -m repro.analysis --fixture <name>`` runs one fixture and exits
  nonzero, printing its rule ids (the acceptance check that the verifier
  actually *fails* on bad inputs, not only passes on good ones);
* ``GraphService`` admission tests submit the bad dataflows as adversarial
  tenant queries.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.flowcheck import check_flow, check_plan
from repro.analysis.tracelint import lint_source
from repro.core.dataflow import Dataflow, OpDesc
from repro.core.plan import ExecutionPlan, PlanNode
from repro.core.query import QueryGraph


def _scan(a: int, b: int) -> OpDesc:
    return OpDesc(kind="scan", schema=(a, b), scan_edge=(a, b))


def dangling_sink_flow() -> Dataflow:
    """An extend branch that never reaches the sink: its rows are dropped."""
    return Dataflow(ops=[
        _scan(0, 1),
        OpDesc(kind="extend", schema=(0, 1, 2), inputs=(0,), ext=(0,),
               new_vertex=2, comm="pull"),          # orphan: nothing consumes it
        _scan(0, 2),
        OpDesc(kind="sink", schema=(0, 2), inputs=(2,)),
    ], query_name="fixture-dangling-sink")


def bad_join_key_flow() -> Dataflow:
    """Join keyed on columns that bind different query vertices per side."""
    return Dataflow(ops=[
        _scan(0, 1),
        _scan(1, 2),
        OpDesc(kind="join", comm="push", schema=(0, 1, 2), inputs=(0, 1),
               key_left=(0,),    # binds v0 on the left...
               key_right=(1,),   # ...but v2 on the right
               right_extra=(1,)),
        OpDesc(kind="sink", schema=(0, 1, 2), inputs=(2,)),
    ], query_name="fixture-bad-join-key")


def disconnected_extend_flow() -> Dataflow:
    """Extend with an empty Eq.-2 intersection set: a cross product."""
    return Dataflow(ops=[
        _scan(0, 1),
        OpDesc(kind="extend", schema=(0, 1, 2), inputs=(0,), ext=(),
               new_vertex=2, comm="pull"),
        OpDesc(kind="sink", schema=(0, 1, 2), inputs=(1,)),
    ], query_name="fixture-disconnected-extend")


def pull_join_flow() -> Dataflow:
    """A materialised join in pull mode — illegal per Eq. 3 / §5.2."""
    return Dataflow(ops=[
        _scan(0, 1),
        _scan(1, 2),
        OpDesc(kind="join", comm="pull", schema=(0, 1, 2), inputs=(0, 1),
               key_left=(1,), key_right=(0,), right_extra=(1,)),
        OpDesc(kind="sink", schema=(0, 1, 2), inputs=(2,)),
    ], query_name="fixture-pull-join")


def oversized_queue_flow() -> Dataflow:
    """A wide, join-heavy flow whose preallocated queues overflow any sane
    slot pool once priced (the queue-cell fixture pairs it with a tiny
    ``max_cells`` budget in the runner below)."""
    ops: List[OpDesc] = [_scan(0, 1)]
    schema = (0, 1)
    for v in range(2, 8):
        ops.append(OpDesc(kind="extend", schema=schema + (v,),
                          inputs=(len(ops) - 1,), ext=(0,), new_vertex=v,
                          comm="pull"))
        schema = schema + (v,)
    ops.append(OpDesc(kind="sink", schema=schema, inputs=(len(ops) - 1,)))
    return Dataflow(ops=ops, query_name="fixture-oversized-queues")


def bad_delta_epoch_flow() -> Dataflow:
    """A would-be delta flow seeded from a *full* scan: its 'old'-epoch
    extend silently drops matches (the old/new split only deduplicates
    matches rooted at a Δ-edge), and one epoch tag is misspelled."""
    return Dataflow(ops=[
        _scan(0, 1),                                 # scan_epoch="full"
        OpDesc(kind="extend", schema=(0, 1, 2), inputs=(0,), ext=(0, 1),
               new_vertex=2, comm="pull", ext_epochs=("old", "stale")),
        OpDesc(kind="sink", schema=(0, 1, 2), inputs=(1,)),
    ], query_name="fixture-bad-delta-epoch")


def disconnected_plan() -> ExecutionPlan:
    """Plan whose join unit is a disconnected edge set (extend order leaves
    the matched prefix)."""
    query = QueryGraph.from_edges([(0, 1), (2, 3), (1, 2)], name="fixture-disc")
    root = PlanNode(edges=frozenset({(0, 1), (2, 3)}))
    return ExecutionPlan(query=query, root=root, symmetry_conditions=())


def illegal_eq3_plan() -> ExecutionPlan:
    """(wco, pull) on a join that is not a complete star join (Def. 3.1)."""
    query = QueryGraph.from_edges([(0, 1), (1, 2), (2, 3), (3, 0)], name="fixture-eq3")
    left = PlanNode(edges=frozenset({(0, 1)}))
    right = PlanNode(edges=frozenset({(1, 2), (2, 3), (0, 3)}))
    root = PlanNode(edges=frozenset(query.edges), left=left, right=right,
                    algo="wco", comm="pull")
    return ExecutionPlan(query=query, root=root, symmetry_conditions=())


BAD_TRACED_SOURCE = '''\
import functools
import jax
import jax.numpy as jnp
import numpy as np
from repro.graph.storage import INVALID


@functools.partial(jax.jit, static_argnames=("cap",))
def bad_step(rows, n, cap):
    if n > 0:                       # traced-branch: n is a tracer
        rows = rows + 1
    total = int(jnp.sum(rows))      # host-sync: int() on a traced value
    host = np.asarray(rows)         # host-sync: forced device->host copy
    assert n < cap                  # traced-branch: assert on a tracer
    return rows, total, host


def make_queue(cap, width):
    buf = jnp.full((cap, width), INVALID)   # queue-dtype: no explicit int32
    return buf
'''


# fixture name -> (runner, expected rule ids). A runner returns diagnostics.
FIXTURES: Dict[str, Tuple[Callable[[], List[Diagnostic]], Tuple[str, ...]]] = {
    "dangling-sink": (lambda: check_flow(dangling_sink_flow()), ("orphan-op",)),
    "bad-join-key": (lambda: check_flow(bad_join_key_flow()),
                     ("join-key-incompatible",)),
    "disconnected-extend": (lambda: check_flow(disconnected_extend_flow()),
                            ("ext-disconnected",)),
    "pull-join": (lambda: check_flow(pull_join_flow()), ("comm-illegal",)),
    "oversized-queues": (lambda: _run_oversized(), ("queue-over-pool",)),
    "retry-slack": (lambda: _run_retry_slack(), ("retry-slack",)),
    "bad-delta-epoch": (lambda: check_flow(bad_delta_epoch_flow()),
                        ("epoch-illegal", "epoch-no-delta-scan")),
    "disconnected-plan": (lambda: check_plan(disconnected_plan()),
                          ("subquery-disconnected",)),
    "illegal-eq3": (lambda: check_plan(illegal_eq3_plan()), ("eq3-illegal",)),
    "bad-kernel-source": (lambda: lint_source(BAD_TRACED_SOURCE, "fixture.py"),
                          ("traced-branch", "host-sync", "queue-dtype")),
}


def _run_oversized() -> List[Diagnostic]:
    from repro.core.engine import EngineConfig

    return check_flow(oversized_queue_flow(), cfg=EngineConfig(), d_pad=64,
                      max_cells=1 << 20)


def _run_retry_slack() -> List[Diagnostic]:
    """A flow that fits its budget at plain pricing but not once the armed
    fault plan doubles the Lemma-5.2 retry slack: the diagnostic must blame
    the recovery headroom (rule ``retry-slack``), not the query size."""
    from repro.core.engine import EngineConfig, flow_queue_cells
    from repro.core.faults import FaultPlan

    flow = oversized_queue_flow()
    ft_cfg = EngineConfig(faults=FaultPlan.single("queue-overflow"),
                          recover=True)
    plain = flow_queue_cells(flow, ft_cfg, 64, None, None,
                             fault_tolerant=False)
    return check_flow(flow, cfg=ft_cfg, d_pad=64, max_cells=plain)


def run_fixture(name: str) -> Tuple[List[Diagnostic], Tuple[str, ...]]:
    runner, expected = FIXTURES[name]
    return runner(), expected
