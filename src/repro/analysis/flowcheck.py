"""Static verifier for execution plans and operator dataflows.

Checks, *without executing* (DESIGN.md §Static-analysis):

``check_flow`` over a translated :class:`~repro.core.dataflow.Dataflow`:

* DAG well-formedness — topological emission order (``inputs[i] < i``), per-
  kind input arity, at least one sink, sinks never consumed, no orphan ops
  (every producer is an ancestor of some sink), and cycle-freedom so every
  PUSH-JOIN barrier (``Dataflow.ancestors`` of its left input) is reachable
  and drainable;
* per-op schema propagation — scan emits its edge's two distinct endpoints,
  extend appends exactly its new vertex, verify preserves its input schema,
  injectivity (no duplicate query vertex per schema), and every
  ``ext`` / ``verify_pos`` / lt/gt order-filter column exists;
* extend-order connectivity — an extend/verify with an empty ``ext`` would
  enumerate a cross product (its new vertex is disconnected from the matched
  prefix), the dataflow-level mirror of ``plan.is_connected`` per sub-query;
* join compatibility — key columns exist on both sides, have equal length,
  and bind the *same query vertices* in the same order; the output schema is
  exactly ``left + right_extra``; cross filters index real columns;
* Eq.-3 comm-mode legality — a materialised join node must be ``push``
  (§5.2 rewrites every pulling join into VERIFY + PULL-EXTENDs before the
  dataflow exists), extends are local/pull/push, scans local;
* delta-epoch legality — only scans carry a ``scan_epoch`` and it is
  ``full`` or ``delta``; ``ext_epochs`` appears only on extend/verify, tags
  every probed adjacency list with ``old``/``new``, and an op probing the
  ``old`` epoch must descend from a delta-seeded scan (the old/new split is
  meaningful only in the exactly-once delta decomposition, DESIGN.md
  §Delta-plans);
* queue-cell accounting — ``engine.flow_queue_cells`` totals against the
  configured Theorem-5.4 bound and/or a ``QueueSlotPool`` capacity, so a
  query that could never be admitted is diagnosed before any lease.

``check_plan`` over an :class:`~repro.core.plan.ExecutionPlan`: sub-query
connectivity per node (``is_connected``), join children partitioning, plan
coverage of the query, Eq.-3 ``(algo, comm)`` legality per join node
(Def. 3.1 / Property 3.1), and symmetry conditions referencing real
vertices. ``check_query`` vets the query graph itself (what a tenant
submits): connectivity and canonical edges.

All three return ``List[Diagnostic]``; ``verify_flow`` raises
:class:`FlowcheckError` on any error-severity finding — the mandatory
pre-flight wired into ``HugeEngine.prepare``, ``DistributedEngine`` runs,
and ``GraphService`` admission.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Set

from repro.analysis.diagnostics import Diagnostic, FlowcheckError, errors
from repro.core.dataflow import Dataflow, OpDesc
from repro.core.plan import (
    ExecutionPlan,
    PlanNode,
    is_complete_star_join,
    is_connected,
    pull_hash_root,
    sub_vertices,
)
from repro.core.query import QueryGraph

_ARITY = {"scan": 0, "extend": 1, "verify": 1, "join": 2, "sink": 1}
_OP_COMM = {
    "scan": ("local",),
    "extend": ("local", "pull", "push"),
    "verify": ("local", "pull"),
    "join": ("push",),          # Eq. 3 / §5.2: pulling joins are rewritten away
    "sink": ("local",),
}


def _diag(rule: str, op: int, msg: str, hint: str = "") -> Diagnostic:
    return Diagnostic(rule=rule, message=msg, op_index=op, hint=hint)


# ---------------------------------------------------------------------------
# Dataflow checks
# ---------------------------------------------------------------------------

def _check_dag(flow: Dataflow, out: List[Diagnostic]) -> bool:
    """Structural DAG checks. Returns False when the graph is too broken for
    the schema pass to walk safely (bad input indices)."""
    ops = flow.ops
    ok = True
    for i, op in enumerate(ops):
        if op.kind not in _ARITY:
            out.append(_diag("op-kind", i, f"unknown operator kind {op.kind!r}",
                             "use scan/extend/verify/join/sink"))
            ok = False
            continue
        if len(op.inputs) != _ARITY[op.kind]:
            out.append(_diag(
                "op-arity", i,
                f"{op.kind} has {len(op.inputs)} inputs, expects {_ARITY[op.kind]}",
                "re-run dataflow.translate; hand-built flows must wire every input",
            ))
            ok = False
        for j in op.inputs:
            if not (0 <= j < len(ops)):
                out.append(_diag("dag-order", i,
                                 f"input {j} outside op range [0, {len(ops)})"))
                ok = False
            elif j >= i:
                out.append(_diag(
                    "dag-order", i,
                    f"input {j} does not precede op {i} (topological emission "
                    "order violated)",
                    "emit producers before consumers (Dataflow contract)",
                ))
                ok = False
            elif ops[j].kind == "sink":
                out.append(_diag("sink-consumed", i,
                                 f"op {i} consumes sink op {j}",
                                 "sinks terminate a flow; nothing reads them"))
    sinks = [i for i, op in enumerate(ops) if op.kind == "sink"]
    if not sinks:
        out.append(_diag("no-sink", len(ops) - 1 if ops else 0,
                         "dataflow has no sink operator",
                         "append a sink so results are counted/materialised"))
        ok = False
    if not ok:
        return False
    # Cycle check via self-reachability (covers barrier reachability: a join
    # inside its own left-branch ancestor set could never release).
    for i, op in enumerate(ops):
        if i in flow.ancestors(i):
            out.append(_diag("dag-cycle", i,
                             f"op {i} is its own ancestor (cycle)",
                             "a PUSH-JOIN barrier over this branch deadlocks"))
            return False
    # Orphans: every non-sink op must feed some sink, else its rows are
    # silently dropped (a dangling branch — typically a mis-merged flow).
    fed: Set[int] = set()
    for s in sinks:
        fed.update(flow.ancestors(s))
    for i, op in enumerate(ops):
        if op.kind != "sink" and i not in fed:
            out.append(_diag(
                "orphan-op", i,
                f"op {i} ({op.label()}) never reaches a sink; its results are dropped",
                "wire the op into a sink's ancestor tree or remove it",
            ))
    return True


def _check_schemas(flow: Dataflow, out: List[Diagnostic]) -> None:
    ops = flow.ops
    for i, op in enumerate(ops):
        schema = op.schema
        if len(set(schema)) != len(schema):
            out.append(_diag(
                "schema-dup", i,
                f"schema {schema} matches a query vertex twice (injectivity broken)",
            ))
        for pos in op.lt_positions + op.gt_positions:
            if not (0 <= pos < len(schema)):
                out.append(_diag(
                    "filter-bounds", i,
                    f"order-filter position {pos} outside schema width {len(schema)}",
                    "symmetry filters must reference matched columns",
                ))
        if op.kind == "scan":
            if op.scan_edge is None or len(schema) != 2 or schema[0] == schema[1]:
                out.append(_diag("schema-scan", i,
                                 f"scan must emit two distinct vertices, got {schema}"))
            elif set(schema) != set(op.scan_edge):
                out.append(_diag(
                    "schema-scan", i,
                    f"scan schema {schema} does not match its edge {op.scan_edge}",
                ))
            continue
        if not op.inputs:
            continue  # arity errors already reported
        in_schema = ops[op.inputs[0]].schema
        if op.kind in ("extend", "verify"):
            if not op.ext:
                out.append(_diag(
                    "ext-disconnected", i,
                    f"{op.kind} intersects zero adjacency lists (Eq. 2 over an "
                    "empty set): the extension is disconnected from the matched "
                    "prefix and would enumerate a cross product",
                    "extend along at least one query edge (plan.is_connected "
                    "per sub-query)",
                ))
            for pos in op.ext:
                if not (0 <= pos < len(in_schema)):
                    out.append(_diag(
                        "ext-bounds", i,
                        f"ext position {pos} outside input schema width {len(in_schema)}",
                    ))
        if op.kind == "extend":
            if op.new_vertex is None:
                out.append(_diag("schema-extend", i, "extend without a new vertex"))
            elif op.new_vertex in in_schema:
                out.append(_diag(
                    "schema-extend", i,
                    f"new vertex v{op.new_vertex} already matched by the input schema",
                ))
            if schema != tuple(in_schema) + ((op.new_vertex,) if op.new_vertex is not None else ()):
                out.append(_diag(
                    "schema-extend", i,
                    f"extend schema {schema} is not input schema {in_schema} + "
                    f"new vertex {op.new_vertex}",
                ))
        elif op.kind == "verify":
            if schema != tuple(in_schema):
                out.append(_diag(
                    "schema-verify", i,
                    f"verify must preserve its input schema, got {schema} from {in_schema}",
                ))
            if op.verify_pos is None or not (0 <= op.verify_pos < len(in_schema)):
                out.append(_diag(
                    "schema-verify", i,
                    f"verify_pos {op.verify_pos} outside input schema width {len(in_schema)}",
                ))
        elif op.kind == "join":
            _check_join(flow, i, out)


def _check_join(flow: Dataflow, i: int, out: List[Diagnostic]) -> None:
    op = flow.ops[i]
    ls = flow.ops[op.inputs[0]].schema
    rs = flow.ops[op.inputs[1]].schema
    if not op.key_left or not op.key_right:
        out.append(_diag("join-key-empty", i,
                         "join with an empty key is a cross product",
                         "key on the common vertices of both input schemas"))
        return
    bad_bounds = False
    for side, key, width in (("left", op.key_left, len(ls)), ("right", op.key_right, len(rs))):
        for pos in key:
            if not (0 <= pos < width):
                out.append(_diag(
                    "join-key-incompatible", i,
                    f"{side} key position {pos} outside schema width {width}",
                ))
                bad_bounds = True
    if len(op.key_left) != len(op.key_right):
        out.append(_diag(
            "join-key-incompatible", i,
            f"key arity differs: left {op.key_left} vs right {op.key_right}",
        ))
        bad_bounds = True
    if not bad_bounds:
        lverts = tuple(ls[p] for p in op.key_left)
        rverts = tuple(rs[p] for p in op.key_right)
        if lverts != rverts:
            out.append(_diag(
                "join-key-incompatible", i,
                f"key columns bind different query vertices: left {lverts} vs "
                f"right {rverts} — rows would match on unrelated vertices",
                "key both sides on the shared vertices, in the same order",
            ))
    extra_ok = all(0 <= p < len(rs) for p in op.right_extra)
    if not extra_ok:
        out.append(_diag("join-schema", i,
                         f"right_extra {op.right_extra} outside right schema width {len(rs)}"))
    else:
        expect = tuple(ls) + tuple(rs[p] for p in op.right_extra)
        if op.schema != expect:
            out.append(_diag(
                "join-schema", i,
                f"join schema {op.schema} != left + right_extra = {expect}",
            ))
        overlap = set(rs[p] for p in op.right_extra) & set(ls)
        if overlap:
            out.append(_diag(
                "join-schema", i,
                f"right_extra re-emits vertices already on the left: {sorted(overlap)}",
            ))
    for a, b in op.cross_neq + op.cross_lt:
        if not (0 <= a < len(op.schema) and 0 <= b < len(op.schema)):
            out.append(_diag(
                "join-cross-bounds", i,
                f"cross filter ({a}, {b}) outside output schema width {len(op.schema)}",
            ))


def _check_comm(flow: Dataflow, out: List[Diagnostic]) -> None:
    for i, op in enumerate(flow.ops):
        legal = _OP_COMM.get(op.kind)
        if legal is not None and op.comm not in legal:
            out.append(_diag(
                "comm-illegal", i,
                f"{op.kind} with comm={op.comm!r}; Eq. 3 allows {legal} "
                "(pulling joins are rewritten to VERIFY + PULL-EXTENDs by §5.2 "
                "before translation)",
                "fix the translator/plan; joins always shuffle (push)",
            ))


_SCAN_EPOCHS = ("full", "delta")
_EXT_EPOCHS = ("old", "new")


def _check_epochs(flow: Dataflow, out: List[Diagnostic]) -> None:
    """Delta-flow epoch legality (DESIGN.md §Delta-plans). A delta dataflow
    is seeded from the update batch (``scan_epoch="delta"``) and threads
    old/new adjacency epochs through its extends/verifies so the k flows of
    a k-edge query emit each new match exactly once; epoch tags anywhere
    else mean a hand-built or mis-merged flow."""
    ops = flow.ops
    for i, op in enumerate(ops):
        if op.kind == "scan":
            if op.scan_epoch not in _SCAN_EPOCHS:
                out.append(_diag(
                    "epoch-illegal", i,
                    f"scan_epoch {op.scan_epoch!r}; a scan is seeded from "
                    f"{_SCAN_EPOCHS} (whole graph vs. update batch)",
                ))
        elif op.scan_epoch != "full":
            out.append(_diag(
                "epoch-illegal", i,
                f"{op.kind} carries scan_epoch={op.scan_epoch!r}; only scans "
                "are seeded from an epoch",
            ))
        if not op.ext_epochs:
            continue
        if op.kind not in ("extend", "verify"):
            out.append(_diag(
                "epoch-illegal", i,
                f"{op.kind} carries ext_epochs={op.ext_epochs}; only "
                "extend/verify probe adjacency epochs",
            ))
            continue
        bad = [e for e in op.ext_epochs if e not in _EXT_EPOCHS]
        if bad:
            out.append(_diag(
                "epoch-illegal", i,
                f"unknown adjacency epoch(s) {bad}; each probed query edge "
                "reads 'old' (pre-batch) or 'new' (post-batch) adjacency",
            ))
        if len(op.ext_epochs) != len(op.ext):
            out.append(_diag(
                "epoch-illegal", i,
                f"{len(op.ext_epochs)} epoch tags for {len(op.ext)} probed "
                "adjacency lists; ext_epochs must tag every ext position",
            ))
        if "old" in op.ext_epochs and not any(
            ops[j].kind == "scan" and ops[j].scan_epoch == "delta"
            for j in flow.ancestors(i)
        ):
            out.append(_diag(
                "epoch-no-delta-scan", i,
                "op probes the 'old' adjacency epoch but no ancestor scan "
                "is seeded from the delta batch: the old/new split only "
                "deduplicates matches rooted at a Δ-edge, so on a full scan "
                "it silently drops matches",
                "seed the flow from a delta scan (dataflow.delta_flows)",
            ))


def check_flow(
    flow: Dataflow,
    *,
    cfg=None,
    d_pad: Optional[int] = None,
    max_cells: Optional[int] = None,
    queue_capacity: Optional[int] = None,
    join_buffer_capacity: Optional[int] = None,
) -> List[Diagnostic]:
    """Statically verify a dataflow. When ``cfg`` (an ``EngineConfig``) and
    ``d_pad`` are given, also price the flow's preallocated queues via
    ``engine.flow_queue_cells`` and check the total against ``max_cells``
    (a Theorem-5.4 budget / ``QueueSlotPool.total_cells``)."""
    out: List[Diagnostic] = []
    if not flow.ops:
        return [_diag("no-sink", 0, "dataflow is empty")]
    if _check_dag(flow, out):
        _check_schemas(flow, out)
        _check_comm(flow, out)
        _check_epochs(flow, out)
    if cfg is not None and d_pad is not None and not errors(out):
        # engine imports this module for its pre-flight; keep the reverse
        # dependency lazy to avoid the cycle.
        from repro.core.engine import flow_queue_cells

        cells = flow_queue_cells(
            flow, cfg, d_pad, queue_capacity, join_buffer_capacity
        )
        if max_cells is not None and cells > max_cells:
            # Fault-tolerant configs double the Lemma-5.2 slack so a degraded
            # retry still fits its queues; when the flow fits the budget at
            # plain pricing but not with that retry slack, say so — the fix
            # is a different knob (disable recovery or grow the pool), not
            # "shrink the query".
            plain = flow_queue_cells(
                flow, cfg, d_pad, queue_capacity, join_buffer_capacity,
                fault_tolerant=False,
            )
            # Anchor on the first sink: merged (multi-sink) flows are legal
            # here, and the whole flow — not one op — is over budget.
            if plain <= max_cells:
                out.append(_diag(
                    "retry-slack", flow.sink_indices()[0],
                    f"flow fits the budget at plain pricing ({plain} cells) "
                    f"but the fault-tolerant retry slack prices it at {cells} "
                    f"> {max_cells}: recovery headroom (doubled Lemma-5.2 "
                    "slack) is what breaks admission",
                    "grow the pool / budget, or disarm faults (recover=False) "
                    "for this engine config",
                ))
            else:
                out.append(_diag(
                    "queue-over-pool", flow.sink_indices()[0],
                    f"flow preallocates {cells} int32 queue cells > budget "
                    f"{max_cells} (Theorem 5.4 bound / slot-pool capacity): it "
                    "could never be admitted",
                    "shrink queue/join-buffer capacities or split the query",
                ))
    return out


def verify_flow(flow: Dataflow, **kwargs) -> None:
    """Raise :class:`FlowcheckError` if ``check_flow`` finds any error."""
    errs = errors(check_flow(flow, **kwargs))
    if errs:
        raise FlowcheckError(errs)


# ---------------------------------------------------------------------------
# Plan / query checks
# ---------------------------------------------------------------------------

def check_query(query: QueryGraph) -> List[Diagnostic]:
    """Vet a query graph as submitted by a tenant (pre-planning)."""
    out: List[Diagnostic] = []
    edges = frozenset(query.edges)
    if not edges:
        out.append(Diagnostic("query-empty", "query has no edges",
                              hint="a pattern needs at least one edge"))
        return out
    verts = sub_vertices(edges)
    if set(range(query.num_vertices)) != set(verts):
        out.append(Diagnostic(
            "query-vertex-gap",
            f"query declares {query.num_vertices} vertices but edges touch "
            f"{sorted(verts)}",
            hint="number vertices densely from 0",
        ))
    if not is_connected(edges):
        out.append(Diagnostic(
            "query-disconnected",
            "query graph is disconnected; enumeration would be a cross "
            "product of components",
            hint="submit each connected component as its own query",
        ))
    for a, b in edges:
        if a == b:
            out.append(Diagnostic("query-self-loop", f"self-loop on v{a}",
                                  hint="simple graphs only"))
    return out


def _walk_plan(node: PlanNode, plan: ExecutionPlan, depth: int,
               out: List[Diagnostic]) -> None:
    where = f"plan-depth-{depth}"
    if not node.edges:
        out.append(Diagnostic("plan-empty-node", f"empty sub-query at {where}",
                              where=where))
        return
    if not is_connected(node.edges):
        out.append(Diagnostic(
            "subquery-disconnected",
            f"sub-query {sorted(node.edges)} at {where} is disconnected "
            "(plan.is_connected): the extend order would leave the matched "
            "prefix and enumerate a cross product",
            where=where,
            hint="every join unit and join result must induce a connected "
                 "subgraph of the query",
        ))
    if node.is_leaf:
        return
    if node.left is None or node.right is None:
        out.append(Diagnostic("join-children", f"join at {where} missing a child",
                              where=where))
        return
    if node.left.edges | node.right.edges != node.edges:
        out.append(Diagnostic(
            "join-children",
            f"join at {where} does not cover its children: "
            f"{sorted(node.left.edges | node.right.edges)} != {sorted(node.edges)}",
            where=where,
        ))
    _check_eq3(node, where, out)
    _walk_plan(node.left, plan, depth + 1, out)
    _walk_plan(node.right, plan, depth + 1, out)


def _check_eq3(node: PlanNode, where: str, out: List[Diagnostic]) -> None:
    """Eq.-3 legality of the join's physical setting (Def. 3.1 / Prop. 3.1)."""
    algo, comm = node.algo, node.comm
    if algo not in ("hash", "wco") or comm not in ("push", "pull"):
        out.append(Diagnostic(
            "eq3-illegal", f"join at {where} has physical setting "
            f"({algo!r}, {comm!r}); Eq. 3 knows (wco|hash, push|pull)",
            where=where,
        ))
        return
    l, r = node.left.edges, node.right.edges
    if algo == "wco":
        # wco = vertex extension as a join: one side must be a complete star
        # join of the other (Definition 3.1), whatever the comm mode.
        if is_complete_star_join(l, r) is None and is_complete_star_join(r, l) is None:
            out.append(Diagnostic(
                "eq3-illegal",
                f"(wco, {comm}) at {where} but neither side is a complete "
                "star join of the other (Def. 3.1)",
                where=where,
                hint="use (hash, push) for general joins",
            ))
    elif comm == "pull":
        # (hash, pull): the right star's root must already be matched on the
        # left (Property 3.1 C1) so §5.2 can rewrite it to VERIFY + extends.
        if pull_hash_root(l, r) is None and pull_hash_root(r, l) is None:
            out.append(Diagnostic(
                "eq3-illegal",
                f"(hash, pull) at {where} but no side is a star rooted at an "
                "already-matched vertex (Property 3.1 C1)",
                where=where,
                hint="use (hash, push): shuffle both sides",
            ))


def check_plan(plan: ExecutionPlan) -> List[Diagnostic]:
    """Statically verify an execution plan (pre-translation)."""
    out: List[Diagnostic] = list(check_query(plan.query))
    qedges = frozenset(plan.query.edges)
    if plan.root.edges != qedges:
        out.append(Diagnostic(
            "plan-cover",
            f"plan covers {sorted(plan.root.edges)} but the query is "
            f"{sorted(qedges)}",
            where="plan-depth-0",
            hint="the root node must carry exactly the query's edge set",
        ))
    nverts = plan.query.num_vertices
    for a, b in plan.symmetry_conditions:
        if not (0 <= a < nverts and 0 <= b < nverts) or a == b:
            out.append(Diagnostic(
                "symmetry-unknown",
                f"symmetry condition v{a} < v{b} references unknown vertices",
                where="plan-depth-0",
            ))
    _walk_plan(plan.root, plan, 0, out)
    return out
