"""Static analysis for the HUGE reproduction: flowcheck + tracelint.

Two passes, one diagnostic format (DESIGN.md §Static-analysis):

* :mod:`repro.analysis.flowcheck` — a static verifier over
  ``ExecutionPlan`` / ``Dataflow`` values: DAG well-formedness, per-op
  schema propagation, Eq.-3 comm-mode legality, extend-order connectivity,
  and Theorem-5.4 queue-cell accounting — all *without executing* the plan.
  Both engines and the multi-tenant ``GraphService`` run it as a mandatory
  pre-flight, so a malformed (or adversarial) query is rejected with a
  structured :class:`Diagnostic` instead of detonating as a shape error
  mid-``shard_map``.
* :mod:`repro.analysis.tracelint` — an AST lint over the source tree for
  tracer-unsafe Python (host syncs and traced-value branching inside
  jitted / shard_map'd functions), dtype drift into the int32 ``[P, CAP, K]``
  queue buffers, and Pallas kernels missing their pure-jnp ref twin or
  parity test.

CLI: ``python -m repro.analysis --all --baseline analysis/baseline.txt``
(the CI ``static-analysis`` job). Existing, justified findings live in the
checked-in baseline file; anything new fails the run.
"""
from __future__ import annotations

from repro.analysis.diagnostics import (
    Diagnostic,
    FlowcheckError,
    format_diagnostics,
    load_baseline,
    split_baselined,
)
from repro.analysis.flowcheck import (
    check_flow,
    check_plan,
    check_query,
    verify_flow,
)

__all__ = [
    "Diagnostic",
    "FlowcheckError",
    "check_flow",
    "check_plan",
    "check_query",
    "verify_flow",
    "format_diagnostics",
    "load_baseline",
    "split_baselined",
    "clean_tree_flowcheck",
]


def clean_tree_flowcheck():
    """Flowcheck every paper query under every plan space (the clean-tree
    corpus the CLI and ``benchmarks.common.record_bench`` certify against).
    Returns the list of diagnostics — expected empty on a healthy tree."""
    from repro.analysis.corpus import corpus_findings

    return corpus_findings()
