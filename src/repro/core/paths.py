"""Paper §6 "Applications": path queries on the HUGE machinery.

Shortest path and hop-constrained s-t simple-path enumeration are expressed
with the same PULL-EXTEND primitive (batched neighbour intersection/expansion
with injectivity filters) and bounded queues the enumeration engine uses:

  * ``shortest_path_length``: repeated PULL-EXTEND frontier expansion from the
    source (vectorised BFS over the padded adjacency) until the target joins
    the frontier.
  * ``hop_constrained_paths``: the paper's suggested bi-directional strategy —
    extend simple paths from both endpoints and PUSH-JOIN them in the middle
    on the meeting vertex (join key), verifying simplicity across the seam.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as ops_mod
from repro.graph.storage import Graph, INVALID


def shortest_path_length(graph: Graph, source: int, target: int, max_hops: int = 64) -> Optional[int]:
    """Unweighted shortest path via vectorised frontier expansion."""
    v = graph.num_vertices
    dist = jnp.full((v,), jnp.iinfo(jnp.int32).max, jnp.int32).at[source].set(0)
    frontier = jnp.zeros((v,), bool).at[source].set(True)
    adj = graph.padded.adj
    for hop in range(1, max_hops + 1):
        # neighbours of the whole frontier in one gather (PULL-EXTEND fetch)
        rows = jnp.where(frontier[:, None], adj, INVALID)
        nxt = jnp.zeros((v + 1,), bool).at[
            jnp.where(rows != INVALID, rows, v).reshape(-1)
        ].set(True)[:v]
        nxt = nxt & (dist == jnp.iinfo(jnp.int32).max)
        if not bool(jnp.any(nxt)):
            return None
        dist = jnp.where(nxt, hop, dist)
        if bool(dist[target] != jnp.iinfo(jnp.int32).max):
            return int(dist[target])
        frontier = nxt
    return None


def _extend_simple_paths(graph: Graph, paths: jnp.ndarray, n: int, cap: int):
    """One PULL-EXTEND over path tails with simplicity (injectivity) filters."""
    k = paths.shape[1]
    out, m = ops_mod.extend_batch(
        graph.padded.adj, paths, jnp.int32(n), ext=(k - 1,), lt=(), gt=(), out_cap=cap
    )
    return out, int(m)


def hop_constrained_paths(
    graph: Graph, source: int, target: int, hops: int, cap: int = 1 << 16
) -> List[Tuple[int, ...]]:
    """All simple s-t paths with exactly ``hops`` edges (bi-directional:
    extend ⌈h/2⌉ from s and ⌊h/2⌋ from t, join on the meeting vertex)."""
    fw_hops = (hops + 1) // 2
    bw_hops = hops - fw_hops

    def grow(start: int, steps: int):
        rows = jnp.full((cap, 1), INVALID, jnp.int32).at[0, 0].set(start)
        n = 1
        for _ in range(steps):
            rows, n = _extend_simple_paths(graph, rows, n, cap)
            if n == 0:
                return rows, 0
        return rows, n

    fw, nf = grow(source, fw_hops)     # [*, fw_hops+1] ending at the middle
    bw, nb = grow(target, bw_hops)     # [*, bw_hops+1] ending at the middle
    if nf == 0 or nb == 0:
        return []

    # PUSH-JOIN on the meeting vertex (last column of both sides).
    kf = fw.shape[1]
    kb = bw.shape[1]
    skeys, sbuf = ops_mod.join_prepare(fw, jnp.int32(nf), (kf - 1,))
    out, m, overflow = ops_mod.join_probe(
        skeys, sbuf, bw, jnp.int32(nb), (kb - 1,),
        tuple(range(kb - 1)),  # append the backward path minus the join vertex
        (), (), cap,
    )
    if bool(overflow):
        raise RuntimeError("path join overflow: raise cap")
    res = np.asarray(out[: int(m)])
    paths = []
    for row in res:
        fwd = [int(x) for x in row[:kf]]
        back = [int(x) for x in row[kf:]][::-1]
        full = fwd + back
        if len(set(full)) == len(full):  # simplicity across the seam
            paths.append(tuple(full))
    return paths
