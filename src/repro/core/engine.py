"""The HUGE engine: dataflow execution with the adaptive scheduler (§4-§5).

This is the single-process reference engine. It executes the full dataflow on
one device while *simulating* the k-machine deployment for communication
accounting exactly as the paper measures it:

  * partial results live on the machine owning their first matched vertex
    (SCAN emits edges from the owner's partition; PULL-EXTEND keeps results
    local; PUSH-JOIN re-partitions by join key);
  * a PULL-EXTEND's fetch stage dedups the batch's remote vertices per
    machine (the paper's merged-RPC aggregation) and runs them through a
    per-machine LRBU cache; cache misses are charged
    ``(deg(v) + 2) * 4`` bytes of pull traffic;
  * PUSH-JOIN charges the shuffle of both inputs; pushing-mode wco extends
    (BiGJoin-style plans) charge ``|ext| · rows · K`` words.

Counts are exact (validated against the networkx oracle); communication and
memory are measured the way Table 1 reports C and M. The true multi-device
engine with real collectives is distributed.py.
"""
from __future__ import annotations

import copy
import dataclasses
import functools
import logging
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as lrbu
from repro.core.faults import (
    EnumerationFault,
    FaultPlan,
    KernelFault,
    QueuePressure,
    ShardLoss,
)
from repro.core import operators as ops_mod
from repro.core.cost import GraphStats
from repro.core.dataflow import (
    Dataflow,
    OpDesc,
    delta_flows,
    merge_flows,
    translate,
)
from repro.core.optimizer import optimal_plan
from repro.core.plan import ExecutionPlan
from repro.core.query import QueryGraph
from repro.core.scheduler import AdaptiveScheduler, ScheduleStats
from repro.graph.storage import (
    AppliedUpdates,
    Graph,
    GraphUpdateBatch,
    INVALID,
    apply_updates as storage_apply_updates,
)

_log = logging.getLogger("repro.engine")


# ---------------------------------------------------------------------------
# Config / stats
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 256
    queue_capacity: int = 1 << 17          # rows per operator output queue
    join_buffer_capacity: int = 1 << 20    # rows buffered per PUSH-JOIN input
    join_out_capacity: int = 1 << 18       # worst-case rows per join step
    num_machines: int = 8                  # simulated cluster size (k)
    cache_capacity: int = 1 << 14          # entries per machine (0 = disabled)
    cache_ways: int = 4
    cache_policy: str = "lrbu"             # "lrbu" | "lru" | "direct"
    materialize: bool = False              # keep final matches (tests only)
    materialize_cap: int = 1 << 20
    use_intersect_kernel: bool = False     # Pallas membership inside extend_batch
    fused: bool = False                    # fused hot path: LRBU value-cache
    #   probe → slab gather → intersect in one kernel pass (extend/verify) and
    #   the compare-count bounds kernel inside PUSH-JOIN probes
    force_kernel: bool = False             # run fused kernels in interpret mode
    #   on CPU (CI parity); otherwise non-TPU backends use the ref twins
    faults: Optional[FaultPlan] = None     # deterministic fault injection
    recover: bool = True                   # graceful-degradation ladder on
    #   recoverable faults (DESIGN.md §Fault-tolerance); False = fail fast
    max_retries: int = 4                   # recovery attempts per driven run
    min_batch_size: int = 32               # degradation floor for batch halving
    checkpoint_every_steps: int = 0        # snapshot cadence inside drive()
    #   (0 = a single snapshot at start; a crash replays the whole query)


@dataclasses.dataclass
class EngineStats:
    count: int = 0
    pulled_bytes: int = 0
    pushed_bytes: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    batches: int = 0
    rows_emitted: int = 0
    compute_time: float = 0.0   # T_R analogue: intersect/join/scan
    comm_time: float = 0.0      # T_C analogue: fetch stage (routing + cache)
    peak_queue_rows: int = 0
    peak_queue_bytes: int = 0
    join_overflows: int = 0
    kernel_fallbacks: int = 0   # fused-kernel failures degraded to the ref twin
    pressure_events: int = 0    # QueuePressure signals absorbed by recovery
    retries: int = 0            # checkpoint restores (pressure + shard loss)
    restarts: int = 0           # of which: shard-loss recoveries
    wall_time: float = 0.0
    per_machine_rows: Optional[np.ndarray] = None

    @property
    def total_comm_bytes(self) -> int:
        return self.pulled_bytes + self.pushed_bytes

    @property
    def hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 0.0


@dataclasses.dataclass
class EnumerationResult:
    count: int
    stats: EngineStats
    schedule: ScheduleStats
    matches: Optional[np.ndarray] = None  # [n, |V_q|] columns in query-vertex order


# ---------------------------------------------------------------------------
# Request routing (fetch stage, Alg. 4 lines 1-9)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_machines", "num_vertices", "r_cap"))
def route_requests(vids, machs, valid, num_machines: int, num_vertices: int, r_cap: int):
    """Dedup (machine, vid) request pairs into per-machine fixed-width lists."""
    big = jnp.int32(num_machines * num_vertices)
    key = jnp.where(valid, machs * num_vertices + vids, big)
    order = jnp.argsort(key)
    ks = jnp.take(key, order)
    valid_s = ks < big
    uniq = valid_s & jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    m_s = jnp.where(valid_s, ks // num_vertices, num_machines)
    v_s = jnp.where(valid_s, ks % num_vertices, INVALID)
    cnt = jax.ops.segment_sum(uniq.astype(jnp.int32), m_s, num_segments=num_machines + 1)[
        :num_machines
    ]
    offs = jnp.cumsum(cnt) - cnt
    offs_ext = jnp.concatenate([offs, jnp.zeros((1,), jnp.int32)])
    grank = jnp.cumsum(uniq.astype(jnp.int32)) - 1
    slot = grank - jnp.take(offs_ext, jnp.minimum(m_s, num_machines))
    tgt_m = jnp.where(uniq, m_s, num_machines)
    tgt_s = jnp.where(uniq, slot, r_cap)
    reqs = jnp.full((num_machines, r_cap), INVALID, jnp.int32).at[tgt_m, tgt_s].set(
        v_s, mode="drop"
    )
    return reqs, cnt


def _make_stacked_cache(num_machines: int, capacity: int, ways: int) -> lrbu.LRBUState:
    sets = max(1, capacity // ways)
    return lrbu.LRBUState(
        keys=jnp.full((num_machines, sets, ways), INVALID, jnp.int32),
        epoch=jnp.full((num_machines, sets, ways), -1, jnp.int32),
        current_epoch=jnp.zeros((num_machines,), jnp.int32),
    )


_POLICIES = {
    "lrbu": lrbu.fetch_update,
    "lru": lrbu.fetch_update_lru,
    "direct": lrbu.fetch_update_direct,
}


# ---------------------------------------------------------------------------
# Device queues
# ---------------------------------------------------------------------------

class DeviceQueue:
    def __init__(self, capacity: int, width: int, label: str = "queue",
                 query: str = ""):
        self.buf = jnp.full((capacity, width), INVALID, jnp.int32)
        self.n = 0  # host-side authoritative count
        self.capacity = capacity
        self.width = width
        self.label = label   # producing op's label (fault attribution)
        self.query = query   # owning dataflow's query name

    def append(self, rows: jax.Array, m) -> int:
        m_host = int(m)
        if self.n + m_host > self.capacity:
            # Recoverable pressure, not a crash: the drive()/service recovery
            # ladder restores the last checkpoint at a halved batch (Lemma 5.2
            # slack is a soft bound under degradation).
            raise QueuePressure(
                "queue-overflow",
                f"{self.n}+{m_host} > {self.capacity} rows "
                "(scheduler slack invariant violated)",
                op=self.label, query=self.query,
            )
        self.buf, _ = ops_mod.queue_append(self.buf, jnp.int32(self.n), rows, m)
        self.n += m_host
        return m_host

    def pop(self, batch: int) -> Tuple[jax.Array, jax.Array]:
        rows, take, _ = ops_mod.queue_pop(self.buf, jnp.int32(self.n), batch)
        self.n -= int(take)
        return rows, take

    def free(self) -> int:
        return self.capacity - self.n

    def bytes_used(self) -> int:
        return self.n * self.width * 4


# ---------------------------------------------------------------------------
# Operator runtimes
# ---------------------------------------------------------------------------

class _BaseRT:
    label = "op"

    def __init__(self, engine: "HugeEngine", desc: OpDesc, out_q: Optional[DeviceQueue]):
        self.e = engine
        self.desc = desc
        self.out_q = out_q
        self.label = desc.label()
        # Per-session batch size: the recovery ladder restores a session at a
        # halved batch without touching the engine config (queue *pricing*
        # stays at cfg.batch_size, so a degraded session's lease is unchanged).
        self.batch = engine.cfg.batch_size
        self.query = ""  # owning dataflow's query name (fault attribution)

    def output_free(self) -> int:
        return self.out_q.free() if self.out_q is not None else 1 << 62

    def required_slack(self) -> int:
        return 0


class _ScanRT(_BaseRT):
    def __init__(self, engine, desc, out_q):
        super().__init__(engine, desc, out_q)
        self.cursor = 0
        self.delta = desc.scan_epoch == "delta"
        if self.delta:
            if engine.delta_adj is None:
                raise RuntimeError(
                    "delta-seeded scan on an engine with no applied update "
                    "batch — call HugeEngine.apply_updates first"
                )
            self.total = int(engine.delta_total)
        else:
            self.total = int(engine.graph.num_directed_edges)

    def has_input(self) -> bool:
        return self.cursor < self.total

    def required_slack(self) -> int:
        return self.batch

    def run_one(self) -> None:
        e = self.e
        e._inject(("queue-overflow", "shard-loss"), self.label, self.query)
        t0 = time.perf_counter()
        src = e.delta_src_pad if self.delta else e.src_pad
        dst = e.delta_dst_pad if self.delta else e.dst_pad
        rows, n = ops_mod.scan_batch(
            src, dst, jnp.int32(self.cursor), jnp.int32(self.total),
            self.batch, self.desc.lt_positions, self.desc.gt_positions,
        )
        self.cursor += self.batch
        m = self.out_q.append(rows, n)
        e.stats.compute_time += time.perf_counter() - t0
        e.stats.batches += 1
        e.stats.rows_emitted += m


class _ExtendRT(_BaseRT):
    def __init__(self, engine, desc, in_q, out_q, comm: str):
        super().__init__(engine, desc, out_q)
        self.in_q = in_q
        self.comm = comm

    def has_input(self) -> bool:
        return self.in_q.n > 0

    def required_slack(self) -> int:
        return self.batch * self.e.d_pad

    def run_one(self) -> None:
        e = self.e
        e._inject(("queue-overflow", "shard-loss"), self.label, self.query)
        rows, n = self.in_q.pop(self.batch)
        if self.comm == "pull":
            e.fetch_stage(rows, n, self.desc.ext)
        elif self.comm == "push":
            e.push_wco_stage(rows, n, len(self.desc.ext), rows.shape[1])
        t0 = time.perf_counter()
        if "old" in self.desc.ext_epochs:
            # Old-epoch positions veto delta membership; the fused kernels
            # know nothing of epochs, so delta extends take the plain path
            # (delta batches are small — this is not the hot loop).
            out, m = ops_mod.delta_extend_batch(
                e.adj, e.delta_adj, rows, n, self.desc.ext,
                tuple(ep == "old" for ep in self.desc.ext_epochs),
                self.desc.lt_positions, self.desc.gt_positions,
                self.batch * e.d_pad,
            )
        elif e.cfg.fused:
            try:
                if e.cfg.faults is not None and e.cfg.faults.should_fire(
                    "kernel-fail", self.label
                ):
                    raise KernelFault("injected fused-kernel failure",
                                      op=self.label, query=self.query)
                tab0, tab1, idx, sel, ok = e._fused_tables(rows, self.desc.ext)
                out, m = ops_mod.fused_extend_batch(
                    tab0, tab1, idx, sel, ok, rows, n,
                    self.desc.lt_positions, self.desc.gt_positions,
                    self.batch * e.d_pad, force_kernel=e.cfg.force_kernel,
                )
            except KernelFault as kf:
                # One-shot graceful degradation: the ref twin is exact, so a
                # failed kernel batch is recomputed unfused instead of failing
                # the query (stat: kernel_fallbacks).
                e.stats.kernel_fallbacks += 1
                _log.warning("fused extend fell back to ref twin: %s", kf)
                out, m = ops_mod.extend_batch(
                    e.adj, rows, n, self.desc.ext, self.desc.lt_positions,
                    self.desc.gt_positions, self.batch * e.d_pad,
                    use_kernel=False,
                )
        else:
            out, m = ops_mod.extend_batch(
                e.adj, rows, n, self.desc.ext, self.desc.lt_positions,
                self.desc.gt_positions, self.batch * e.d_pad,
                use_kernel=e.cfg.use_intersect_kernel,
            )
        cnt = self.out_q.append(out, m)
        e.stats.compute_time += time.perf_counter() - t0
        e.stats.batches += 1
        e.stats.rows_emitted += cnt


class _VerifyRT(_BaseRT):
    def __init__(self, engine, desc, in_q, out_q, comm: str):
        super().__init__(engine, desc, out_q)
        self.in_q = in_q
        self.comm = comm

    def has_input(self) -> bool:
        return self.in_q.n > 0

    def required_slack(self) -> int:
        return self.batch

    def run_one(self) -> None:
        e = self.e
        e._inject(("queue-overflow", "shard-loss"), self.label, self.query)
        rows, n = self.in_q.pop(self.batch)
        if self.comm == "pull":
            e.fetch_stage(rows, n, self.desc.ext)
        t0 = time.perf_counter()
        if "old" in self.desc.ext_epochs:
            out, m = ops_mod.delta_verify_batch(
                e.adj, e.delta_adj, rows, n, self.desc.ext,
                tuple(ep == "old" for ep in self.desc.ext_epochs),
                self.desc.verify_pos, self.batch,
            )
        elif e.cfg.fused:
            try:
                if e.cfg.faults is not None and e.cfg.faults.should_fire(
                    "kernel-fail", self.label
                ):
                    raise KernelFault("injected fused-kernel failure",
                                      op=self.label, query=self.query)
                tab0, tab1, idx, sel, ok = e._fused_tables(rows, self.desc.ext)
                out, m = ops_mod.fused_verify_batch(
                    tab0, tab1, idx, sel, ok, rows, n, self.desc.verify_pos,
                    self.batch, force_kernel=e.cfg.force_kernel,
                )
            except KernelFault as kf:
                e.stats.kernel_fallbacks += 1
                _log.warning("fused verify fell back to ref twin: %s", kf)
                out, m = ops_mod.verify_batch(
                    e.adj, rows, n, self.desc.ext, self.desc.verify_pos,
                    self.batch,
                )
        else:
            out, m = ops_mod.verify_batch(
                e.adj, rows, n, self.desc.ext, self.desc.verify_pos, self.batch
            )
        cnt = self.out_q.append(out, m)
        e.stats.compute_time += time.perf_counter() - t0
        e.stats.batches += 1
        e.stats.rows_emitted += cnt


class _JoinRT(_BaseRT):
    """PUSH-JOIN: the left input is fully buffered (barrier, §5.4), then the
    right queue is streamed batch-wise against it. The barrier is expressed
    through ``has_input``: the join reports no input until every ancestor of
    its left branch has drained (``left_branch_done``, wired by the engine
    from Dataflow.ancestors), so the generalised AdaptiveScheduler drives
    whole DAGs without per-branch sub-schedulers."""

    def __init__(self, engine, desc, left_q, right_q, out_q):
        super().__init__(engine, desc, out_q)
        self.left_q = left_q
        self.right_q = right_q
        self.shuffle_charged = False
        self._prepared = None  # (sorted_keys, sorted_buf) once left side final
        self.left_branch_done = lambda: True  # installed by the engine

    def has_input(self) -> bool:
        return self.right_q.n > 0 and self.left_branch_done()

    def required_slack(self) -> int:
        return self.e.cfg.join_out_capacity

    def run_one(self) -> None:
        e = self.e
        e._inject(("join-overflow", "shard-loss"), self.label, self.query)
        frac = (e.cfg.num_machines - 1) / max(1, e.cfg.num_machines)
        if not self.shuffle_charged:
            # Left side is complete at the barrier: charge its shuffle once.
            # The right side streams, so it is charged per popped batch below.
            e.stats.pushed_bytes += int(self.left_q.n * self.left_q.width * 4 * frac)
            self.shuffle_charged = True
        if self._prepared is None:
            # The left branch is complete (barrier, §5.4): merge-sort it by key
            # once — the paper's buffered external sort.
            t0 = time.perf_counter()
            self._prepared = ops_mod.join_prepare(
                self.left_q.buf, jnp.int32(self.left_q.n), self.desc.key_left
            )
            e.stats.compute_time += time.perf_counter() - t0
        rrows, rn = self.right_q.pop(max(64, self.batch))
        e.stats.pushed_bytes += int(int(rn) * self.right_q.width * 4 * frac)
        t0 = time.perf_counter()
        use_kernel = e.cfg.fused
        if use_kernel and e.cfg.faults is not None and e.cfg.faults.should_fire(
            "kernel-fail", self.label
        ):
            # One-shot degradation for the probe's bounds kernel: the binary-
            # search ref path is exact, so the batch recomputes unfused.
            e.stats.kernel_fallbacks += 1
            _log.warning("join probe kernel failed at op=%s; using ref bounds",
                         self.label)
            use_kernel = False
        out, m, overflow = ops_mod.join_probe(
            self._prepared[0], self._prepared[1], rrows, rn,
            self.desc.key_right, self.desc.right_extra,
            self.desc.cross_neq, self.desc.cross_lt, e.cfg.join_out_capacity,
            use_kernel=use_kernel, force_kernel=e.cfg.force_kernel,
        )
        if bool(overflow):
            e.stats.join_overflows += 1
            raise QueuePressure(
                "join-overflow",
                f"probe output exceeded join_out_capacity="
                f"{e.cfg.join_out_capacity} with right batch {int(rn)} "
                "(results would be lost)",
                op=self.label, query=self.query,
            )
        cnt = self.out_q.append(out, m)
        e.stats.compute_time += time.perf_counter() - t0
        e.stats.batches += 1
        e.stats.rows_emitted += cnt


class _SinkRT(_BaseRT):
    def __init__(self, engine, desc, in_q):
        super().__init__(engine, desc, None)
        self.in_q = in_q
        self.rows_out: List[np.ndarray] = []
        # Drain in large fixed-size chunks (one jit signature).
        self.drain = min(in_q.capacity, max(engine.cfg.batch_size * engine.d_pad, 1 << 15))

    def has_input(self) -> bool:
        return self.in_q.n > 0

    def run_one(self) -> None:
        e = self.e
        rows, n = self.in_q.pop(self.drain)
        n_host = int(n)
        e.stats.count += n_host
        if e.cfg.materialize and sum(r.shape[0] for r in self.rows_out) < e.cfg.materialize_cap:
            host = np.asarray(rows[:n_host] if n_host <= rows.shape[0] else rows)
            self.rows_out.append(host[:n_host])
        # Track per-machine result distribution for the load-balance experiment.
        if e.track_balance and n_host:
            host = np.asarray(rows)[:n_host]
            owners = host[:, 0] % e.cfg.num_machines
            np.add.at(e.balance_rows, owners, 1)
        e.stats.batches += 1


# ---------------------------------------------------------------------------
# Multi-tenant building blocks (serve/graph_service.py)
# ---------------------------------------------------------------------------

class QueueSlotPool:
    """Aggregate queue budget shared by every session on one engine.

    Theorem 5.4 bounds a single query's intermediate state by O(|V_q|²·D_G);
    the pool turns that into a *service* invariant: each admitted query leases
    the int32 cells (rows × width) its preallocated queues will occupy, and
    admission fails — queueing the request instead of OOMing the device —
    once the aggregate lease would exceed ``total_cells``. Releases happen
    when a query completes or is cancelled, freeing its slice for the
    admission queue (DESIGN.md §Graph-service)."""

    def __init__(self, total_cells: int):
        self.total_cells = int(total_cells)
        self.leased_cells = 0

    def free_cells(self) -> int:
        return self.total_cells - self.leased_cells

    def try_lease(self, cells: int) -> bool:
        if cells > self.free_cells():
            return False
        self.leased_cells += cells
        return True

    def release(self, cells: int) -> None:
        # Not an assert (stripped under python -O): over-release is pool-
        # accounting corruption — clamp so the pool stays usable, then raise
        # with the offending lease size so the caller is attributable.
        if cells > self.leased_cells:
            leaked = cells - self.leased_cells
            _log.error(
                "queue-slot pool over-release: released %d cells with only %d "
                "leased (%d excess)", cells, self.leased_cells, leaked,
            )
            self.leased_cells = 0
            raise RuntimeError(
                f"queue-slot pool released {cells} cells but only "
                f"{cells - leaked} were leased (over-release of {leaked})"
            )
        self.leased_cells -= cells


class _ScopedRT:
    """OperatorRuntime view that charges its work to one session's stats.

    Sessions from different tenants interleave inside a *single* scheduler
    pass, so per-tenant attribution can't happen at pass granularity: the
    wrapper swaps the engine's stats target around each ``run_one`` (every
    stats mutation — runtimes, fetch_stage, push accounting — goes through
    ``engine.stats``), keeping the underlying runtimes untouched."""

    __slots__ = ("rt", "e", "stats", "label", "session")

    def __init__(self, rt: _BaseRT, engine: "HugeEngine", stats: EngineStats,
                 session: "EngineSession" = None):
        self.rt = rt
        self.e = engine
        self.stats = stats
        self.label = rt.label
        self.session = session

    def has_input(self) -> bool:
        return self.rt.has_input()

    def output_free(self) -> int:
        return self.rt.output_free()

    def required_slack(self) -> int:
        return self.rt.required_slack()

    def run_one(self) -> None:
        prev = self.e.stats
        self.e.stats = self.stats
        try:
            self.rt.run_one()
        except EnumerationFault as f:
            # Attribute the fault to the owning session so a multi-tenant
            # scheduler pass can fail/recover exactly one query.
            f.session = self.session
            raise
        finally:
            self.e.stats = prev


def fault_tolerant_sizing(cfg: EngineConfig) -> bool:
    """Whether queue sizing must include retry slack: true when a fault plan
    is armed *and* the recovery ladder is on (a recovered retry replays a
    checkpointed batch while the original batch may still occupy its queue,
    so each queue needs a second worst-case batch of Lemma 5.2 slack)."""
    return getattr(cfg, "faults", None) is not None and getattr(
        cfg, "recover", False
    )


def _queue_plan(
    flow: Dataflow,
    cfg: EngineConfig,
    d_pad: int,
    queue_capacity: int | None = None,
    join_buffer_capacity: int | None = None,
    fault_tolerant: bool | None = None,
) -> Dict[int, Tuple[int, int]]:
    """Queue sizing for a dataflow: ``{op_index: (physical_rows, width)}``.

    An op feeding a PUSH-JOIN buffers its side fully; every queue carries one
    worst-case batch of slack on top (the Lemma 5.2 overflow allowance — also
    what lets a join feed another join). Fault-tolerant configs (armed fault
    plan + recovery on) double that slack: a post-restore retry can re-append
    a replayed batch on top of rows the original attempt already parked
    (flowcheck rule ``retry-slack`` catches pricing that ignores this).
    Shared by session allocation and by the service's admission check (which
    must price a query *before* paying for it)."""
    qcap = cfg.queue_capacity if queue_capacity is None else queue_capacity
    jcap = cfg.join_buffer_capacity if join_buffer_capacity is None else join_buffer_capacity
    if fault_tolerant is None:
        fault_tolerant = fault_tolerant_sizing(cfg)
    slack_mult = 2 if fault_tolerant else 1
    succ: Dict[int, int] = {}
    for i, op in enumerate(flow.ops):
        for j in op.inputs:
            succ[j] = i
    plan: Dict[int, Tuple[int, int]] = {}
    for i, op in enumerate(flow.ops):
        if op.kind == "sink":
            continue
        slack = {
            "scan": cfg.batch_size,
            "verify": cfg.batch_size,
            "extend": cfg.batch_size * d_pad,
            "join": cfg.join_out_capacity,
        }[op.kind] * slack_mult
        s = succ.get(i)
        if s is not None and flow.ops[s].kind == "join":
            cap = jcap + slack
        else:
            cap = qcap + slack
        plan[i] = (cap, len(op.schema))
    return plan


def flow_queue_cells(
    flow: Dataflow,
    cfg: EngineConfig,
    d_pad: int,
    queue_capacity: int | None = None,
    join_buffer_capacity: int | None = None,
    fault_tolerant: bool | None = None,
) -> int:
    """Total int32 cells a session over ``flow`` will preallocate — the
    quantity a ``QueueSlotPool`` lease is denominated in. ``fault_tolerant``
    defaults to deriving from ``cfg`` (see ``fault_tolerant_sizing``), so
    pricing and allocation always agree."""
    return sum(
        cap * width
        for cap, width in _queue_plan(
            flow, cfg, d_pad, queue_capacity, join_buffer_capacity,
            fault_tolerant,
        ).values()
    )


class EngineSession:
    """One query's execution state on a shared engine: its slot-slice of
    device queues, its operator runtimes (barrier-wired), and its private
    stats. Sessions are driven either to completion (``run``, what
    ``HugeEngine.run`` does) or cooperatively in bounded ticks interleaved
    with other tenants' sessions (``chain`` handed to one shared
    ``AdaptiveScheduler`` per service tick — serve/graph_service.py)."""

    def __init__(
        self,
        engine: "HugeEngine",
        flow: Dataflow,
        stats: EngineStats | None = None,
        queue_capacity: int | None = None,
        join_buffer_capacity: int | None = None,
        batch_size: int | None = None,
        dfs_bias: bool = False,
    ):
        self.engine = engine
        self.flow = flow
        self.stats = stats if stats is not None else EngineStats()
        self.sched_stats = ScheduleStats()
        # Per-session degradation state: a restored session may run a smaller
        # batch with a DFS-biased scheduler while keeping cfg-priced queues
        # (so its QueueSlotPool lease is unchanged).
        self.batch_size = int(batch_size) if batch_size else engine.cfg.batch_size
        self.dfs_bias = dfs_bias
        ops = flow.ops
        plan = _queue_plan(flow, engine.cfg, engine.d_pad,
                           queue_capacity, join_buffer_capacity)
        self.queues: Dict[int, DeviceQueue] = {
            i: DeviceQueue(cap, width, label=ops[i].label(),
                           query=flow.query_name)
            for i, (cap, width) in plan.items()
        }
        self.queue_cells = sum(cap * width for cap, width in plan.values())

        self.runtimes: Dict[int, _BaseRT] = {}
        for i, op in enumerate(ops):
            q = self.queues.get(i)
            if op.kind == "scan":
                self.runtimes[i] = _ScanRT(engine, op, q)
            elif op.kind == "extend":
                self.runtimes[i] = _ExtendRT(
                    engine, op, self.queues[op.inputs[0]], q, op.comm
                )
            elif op.kind == "verify":
                self.runtimes[i] = _VerifyRT(
                    engine, op, self.queues[op.inputs[0]], q, "pull"
                )
            elif op.kind == "join":
                self.runtimes[i] = _JoinRT(
                    engine, op, self.queues[op.inputs[0]],
                    self.queues[op.inputs[1]], q,
                )
            else:
                self.runtimes[i] = _SinkRT(engine, op, self.queues[op.inputs[0]])
        for rt in self.runtimes.values():
            rt.batch = self.batch_size
            rt.query = flow.query_name

        # Join barriers: a PUSH-JOIN may only probe once every ancestor of its
        # left (buffered) input has drained. With the barrier inside each
        # join's has_input, one generalised scheduler pass over the dataflow's
        # topological order executes the whole DAG.
        runtimes = self.runtimes
        for i, op in enumerate(ops):
            if op.kind != "join":
                continue
            branch = (*flow.ancestors(op.inputs[0]), op.inputs[0])

            def make_done(branch=branch):
                def done() -> bool:
                    return not any(runtimes[j].has_input() for j in branch)
                return done

            runtimes[i].left_branch_done = make_done()

        # Topologically ordered, stats-scoped view for shared scheduler passes.
        self.chain = [
            _ScopedRT(self.runtimes[i], engine, self.stats, session=self)
            for i in range(len(ops))
        ]

    # -- introspection -------------------------------------------------------

    def done(self) -> bool:
        """True once every operator has drained (same criterion that ends a
        dedicated scheduler pass, so a finished session never resumes)."""
        return not any(rt.has_input() for rt in self.runtimes.values())

    def rows_in_flight(self) -> int:
        return sum(q.n for q in self.queues.values())

    def bytes_in_flight(self) -> int:
        return sum(q.bytes_used() for q in self.queues.values())

    def memory_probe(self) -> Tuple[int, int]:
        return self.rows_in_flight(), self.bytes_in_flight()

    # -- checkpoint / resume (DESIGN.md §Fault-tolerance) --------------------

    def snapshot(self) -> Dict[str, object]:
        """Host-side capture of the session's complete execution state.

        Taken *between* scheduler steps, queue contents plus the host-side
        cursors (scan position, join shuffle flag, sink rows, stats) are the
        entire state — all device arrays other than queue rows are immutable
        graph data. ``restore`` therefore resumes exactly-once-correct:
        stats roll back to the snapshot, so rows replayed after a restore are
        never double-counted. Shuffle-byte accounting for already-popped join
        batches may be re-charged on replay (counts stay exact; comm stats
        are approximate under recovery)."""
        queues: Dict[int, Tuple[np.ndarray, int]] = {}
        for i, q in self.queues.items():
            rows = (
                np.asarray(q.buf[: q.n]).copy()
                if q.n
                else np.zeros((0, q.width), np.int32)
            )
            queues[i] = (rows, q.n)
        return {
            "query": self.flow.query_name,
            "batch_size": self.batch_size,
            "queues": queues,
            "scan_cursors": {
                i: rt.cursor
                for i, rt in self.runtimes.items()
                if isinstance(rt, _ScanRT)
            },
            "join_charged": {
                i: rt.shuffle_charged
                for i, rt in self.runtimes.items()
                if isinstance(rt, _JoinRT)
            },
            "sink_rows": {
                i: [r.copy() for r in rt.rows_out]
                for i, rt in self.runtimes.items()
                if isinstance(rt, _SinkRT)
            },
            "stats": copy.copy(self.stats),
            "sched_stats": copy.copy(self.sched_stats),
        }

    @classmethod
    def restore(
        cls,
        engine: "HugeEngine",
        flow: Dataflow,
        snap: Dict[str, object],
        *,
        stats: EngineStats | None = None,
        queue_capacity: int | None = None,
        join_buffer_capacity: int | None = None,
        batch_size: int | None = None,
        dfs_bias: bool = False,
    ) -> "EngineSession":
        """Rebuild a session from ``snapshot()``, optionally degraded to a
        smaller ``batch_size`` (the recovery ladder's halving). Queue
        capacities come from the same pricing as a fresh session, so a
        restored query's slot-pool lease is identical to the original's.
        When ``stats`` is supplied (e.g. the ticket's stats object), snapshot
        values are written into it in place so existing references stay
        valid."""
        if snap.get("query") not in ("", None, flow.query_name):
            raise ValueError(
                f"snapshot is for query {snap['query']!r}, not "
                f"{flow.query_name!r}"
            )
        sess = cls(
            engine, flow, stats=stats, queue_capacity=queue_capacity,
            join_buffer_capacity=join_buffer_capacity,
            batch_size=batch_size or snap["batch_size"], dfs_bias=dfs_bias,
        )
        for i, (rows, n) in snap["queues"].items():
            q = sess.queues[i]
            if n > q.capacity:
                raise ValueError(
                    f"snapshot queue {i} holds {n} rows but the restored "
                    f"queue caps at {q.capacity}"
                )
            if n:
                q.buf = q.buf.at[:n].set(jnp.asarray(rows))
            q.n = int(n)
        for i, cur in snap["scan_cursors"].items():
            sess.runtimes[i].cursor = int(cur)
        for i, charged in snap["join_charged"].items():
            sess.runtimes[i].shuffle_charged = bool(charged)
        for i, rows in snap["sink_rows"].items():
            sess.runtimes[i].rows_out = [r.copy() for r in rows]
        sess.stats.__dict__.update(copy.copy(snap["stats"]).__dict__)
        sess.sched_stats.__dict__.update(copy.copy(snap["sched_stats"]).__dict__)
        return sess

    # -- execution -----------------------------------------------------------

    def tick(self, max_steps: int) -> ScheduleStats:
        """Run up to ``max_steps`` operator batches of this session only
        (single-tenant cooperative slice; the multi-tenant service instead
        concatenates several sessions' chains into one pass)."""
        st = AdaptiveScheduler(
            self.chain, memory_probe=self.memory_probe, dfs_bias=self.dfs_bias
        ).run(max_steps)
        self.sched_stats.merge(st)
        return st

    def run(self) -> ScheduleStats:
        st = AdaptiveScheduler(
            self.chain, memory_probe=self.memory_probe, dfs_bias=self.dfs_bias
        ).run()
        self.sched_stats.merge(st)
        return st

    def result(self) -> EnumerationResult:
        self.stats.peak_queue_rows = self.sched_stats.peak_queue_rows
        self.stats.peak_queue_bytes = self.sched_stats.peak_queue_bytes
        # All sinks, not ops[-1]: a merged flow (merge_flows — multi-tenant
        # service, delta unions) has one sink per source flow, and each sink's
        # schema may order the query vertices differently. Materialised rows
        # are permuted into ascending query-vertex column order before
        # concatenation so the result is one coherent [n, |V_q|] table.
        matches = None
        if self.engine.cfg.materialize:
            chunks: List[np.ndarray] = []
            for si in self.flow.sink_indices():
                sink_rt = self.runtimes[si]
                if not (isinstance(sink_rt, _SinkRT) and sink_rt.rows_out):
                    continue
                rows = np.concatenate(sink_rt.rows_out, axis=0)
                schema = self.flow.ops[si].schema
                perm = [schema.index(v) for v in sorted(schema)]
                chunks.append(rows[:, perm])
            if chunks:
                matches = np.concatenate(chunks, axis=0)
        return EnumerationResult(
            count=self.stats.count, stats=self.stats,
            schedule=self.sched_stats, matches=matches,
        )


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

def _edge_scan_arrays(graph: Graph, batch: int) -> Tuple[jax.Array, jax.Array]:
    """Directed edge arrays padded to a batch multiple (scan_batch's contract)."""
    offsets = np.asarray(graph.offsets)
    deg_np = np.diff(offsets)
    src = np.repeat(np.arange(graph.num_vertices, dtype=np.int32), deg_np)
    dst = np.asarray(graph.nbrs, dtype=np.int32)
    pad = (-len(src)) % batch + batch
    return (
        jnp.asarray(np.concatenate([src, np.full(pad, 0, np.int32)])),
        jnp.asarray(np.concatenate([dst, np.full(pad, INVALID, np.int32)])),
    )


class HugeEngine:
    def __init__(self, graph: Graph, cfg: EngineConfig | None = None, track_balance: bool = False):
        self.cfg = cfg or EngineConfig()
        self._load_graph(graph)
        self.stats = EngineStats()
        self.track_balance = track_balance
        self.balance_rows = np.zeros(self.cfg.num_machines, dtype=np.int64)
        self._reset_caches()
        # Delta state (streaming): installed by apply_updates.
        self.delta_adj: Optional[jax.Array] = None
        self.delta_src_pad: Optional[jax.Array] = None
        self.delta_dst_pad: Optional[jax.Array] = None
        self.delta_total: int = 0

    def _load_graph(self, graph: Graph) -> None:
        """(Re)bind every graph-derived array — also the update path's spine."""
        self.graph = graph
        self.adj = graph.padded.adj
        self.deg = graph.padded.deg
        self.d_pad = graph.padded.d_pad
        assert graph.num_vertices * self.cfg.num_machines < 2**31, (
            "machine-id × vertex-id key must fit int32"
        )
        self.src_pad, self.dst_pad = _edge_scan_arrays(graph, self.cfg.batch_size)

    def _reset_caches(self) -> None:
        """Build (or rebuild) the fetch caches from scratch. Called at init
        and after every apply_updates — cached adjacency slabs and hit/miss
        bookkeeping are stale the moment the graph mutates."""
        self._cache = None
        if self.cfg.cache_capacity > 0:
            ways = 1 if self.cfg.cache_policy == "direct" else self.cfg.cache_ways
            self._cache = _make_stacked_cache(
                self.cfg.num_machines, self.cfg.cache_capacity, ways
            )
            self._cache_update = jax.vmap(_POLICIES[self.cfg.cache_policy])
        # Device-level LRBU *value* cache serving adjacency slabs to the fused
        # kernels (the per-machine caches above are stats-only simulation).
        self._vcache = None
        if self.cfg.fused and self.cfg.cache_capacity > 0:
            self._vcache = lrbu.make_cache(
                self.cfg.cache_capacity, ways=self.cfg.cache_ways, d_pad=self.d_pad
            )

    # -- streaming updates (DESIGN.md §Delta-plans) ----------------------------

    def apply_updates(self, batch: GraphUpdateBatch) -> AppliedUpdates:
        """Apply an edge-insert batch and arm the delta execution state.

        Row-local storage rebuild (graph/storage.apply_updates), then every
        graph-derived array is rebound and both fetch caches are dropped —
        a cached slab from the pre-batch graph would silently corrupt Eq.-2
        intersections. The delta graph (genuinely-new edges only) becomes the
        seed for delta-seeded scans and the old-epoch membership veto."""
        applied = storage_apply_updates(self.graph, batch)
        self._load_graph(applied.graph)
        self._reset_caches()
        delta = applied.delta
        self.delta_adj = delta.padded.adj
        self.delta_src_pad, self.delta_dst_pad = _edge_scan_arrays(
            delta, self.cfg.batch_size
        )
        self.delta_total = int(delta.num_directed_edges)
        return applied

    def run_delta(
        self,
        query_or_plan: QueryGraph | ExecutionPlan,
        space: str = "huge",
        stats: GraphStats | None = None,
    ) -> EnumerationResult:
        """Enumerate only the matches *created* by the last applied batch.

        Executes the delta-join decomposition (dataflow.delta_flows): one
        delta-seeded flow per query edge, merged into a single multi-sink DAG
        so one scheduler pass interleaves all k flows through the standard
        EngineSession/AdaptiveScheduler machinery. Exactly-once: a new match
        is produced by the flow of its minimum-index delta query edge."""
        if self.delta_adj is None:
            raise RuntimeError(
                "run_delta before apply_updates: no delta batch is armed"
            )
        if isinstance(query_or_plan, QueryGraph):
            gstats = stats or GraphStats.from_graph(self.graph)
            plan = optimal_plan(query_or_plan, gstats, self.cfg.num_machines, space)
        elif isinstance(query_or_plan, ExecutionPlan):
            plan = query_or_plan
        else:
            raise TypeError(
                "run_delta needs a QueryGraph or ExecutionPlan (delta flows "
                "are derived from the query, not from an existing Dataflow)"
            )
        t_start = time.perf_counter()
        flows = delta_flows(plan)
        merged, _ = merge_flows(flows)
        session = self.prepare(merged)
        self._queues = session.queues
        session = self.drive(session)
        result = session.result()
        result.stats.wall_time = time.perf_counter() - t_start
        return result

    # -- fetch stage (pull accounting) ---------------------------------------

    def fetch_stage(self, rows: jax.Array, n: jax.Array, ext: Tuple[int, ...]) -> None:
        t0 = time.perf_counter()
        cfg = self.cfg
        b, k = rows.shape
        row_valid = jnp.arange(b) < n
        shard = jnp.where(rows[:, 0] >= 0, rows[:, 0] % cfg.num_machines, 0)
        vids = rows[:, list(ext)]                       # [B, E]
        machs = jnp.broadcast_to(shard[:, None], vids.shape)
        remote = (vids % cfg.num_machines) != machs
        valid = (
            row_valid[:, None] & (vids != INVALID) & (vids >= 0) & remote
        )
        vids_f = vids.reshape(-1)
        machs_f = machs.reshape(-1)
        valid_f = valid.reshape(-1)
        reqs, cnt = route_requests(
            vids_f, machs_f, valid_f, cfg.num_machines, self.graph.num_vertices,
            r_cap=vids_f.shape[0],
        )
        req_valid = reqs != INVALID
        if self._cache is not None:
            self._cache, hit = self._cache_update(self._cache, reqs)
            hit = hit & req_valid
        else:
            hit = jnp.zeros_like(req_valid)
        miss = req_valid & ~hit
        degs = jnp.where(
            miss, jnp.take(self.deg, jnp.clip(reqs, 0, self.graph.num_vertices - 1)), 0
        )
        pulled = jnp.sum((degs + 2) * 4 * miss)
        self.stats.pulled_bytes += int(pulled)
        self.stats.cache_hits += int(jnp.sum(hit))
        self.stats.cache_misses += int(jnp.sum(miss))
        self.stats.comm_time += time.perf_counter() - t0

    # -- fused hot path: value-cache probe prologue ----------------------------

    def _fused_tables(self, rows: jax.Array, ext: Tuple[int, ...]):
        """Build the (tab0, tab1, idx, sel, ok) slab addressing of the fused
        kernels for one batch: insert the batch's deduped vertices into the
        LRBU value cache (seal/release), then probe it — hits read cache slabs
        (tab0), misses fall back to the adjacency table (tab1)."""
        v = self.graph.num_vertices
        vids = rows[:, list(ext)]                       # [B, E]
        ok = (vids >= 0) & (vids < v)
        idx1 = jnp.clip(vids, 0, v - 1)
        if self._vcache is not None:
            flat = jnp.where(ok, vids, INVALID).reshape(-1)
            uniq = ops_mod.dedup_pad(flat)
            safe = jnp.clip(uniq, 0, v - 1)
            slabs = jnp.take(self.adj, safe, axis=0)
            degs = jnp.where(uniq != INVALID, jnp.take(self.deg, safe), 0)
            self._vcache, _ = lrbu.fetch_update_values(self._vcache, uniq, slabs, degs)
            idx0, hit = lrbu.probe_indices(self._vcache, flat)
            tab0 = self._vcache.values.reshape(-1, self.d_pad)
            idx0 = idx0.reshape(vids.shape)
            sel = hit.reshape(vids.shape)
        else:
            tab0 = self.adj[:1]
            idx0 = jnp.zeros_like(idx1)
            sel = jnp.zeros(vids.shape, bool)
        idx = jnp.stack([idx0, idx1])
        return tab0, self.adj, idx, sel.astype(jnp.int32), ok.astype(jnp.int32)

    # -- push accounting for wco-push extends (BiGJoin-style plans) -----------

    def push_wco_stage(self, rows: jax.Array, n: jax.Array, n_ext: int, k: int) -> None:
        frac = (self.cfg.num_machines - 1) / max(1, self.cfg.num_machines)
        self.stats.pushed_bytes += int(int(n) * k * 4 * n_ext * frac)

    # -- memory probe ----------------------------------------------------------

    def _memory_probe(self):
        rows = sum(q.n for q in self._queues.values())
        nbytes = sum(q.bytes_used() for q in self._queues.values())
        return rows, nbytes

    # -- fault injection (core/faults.py) --------------------------------------

    def _inject(self, kinds: Tuple[str, ...], op: str, query: str = "") -> None:
        """Probe the armed FaultPlan at an operator invocation and raise the
        matching structured fault. Host-side only — never reached from traced
        code, so jit caches are fault-agnostic."""
        fp = self.cfg.faults
        if fp is None:
            return
        for kind in kinds:
            if fp.should_fire(kind, op):
                if kind == "shard-loss":
                    raise ShardLoss(fp.seed % self.cfg.num_machines,
                                    op=op, query=query)
                raise QueuePressure(kind, "injected fault", op=op, query=query)

    # -- execution --------------------------------------------------------------

    def to_flow(
        self,
        query_or_plan: QueryGraph | ExecutionPlan | Dataflow,
        space: str = "huge",
        stats: GraphStats | None = None,
    ) -> Dataflow:
        """Resolve a query / plan / dataflow into an executable dataflow."""
        if isinstance(query_or_plan, Dataflow):
            return query_or_plan
        if isinstance(query_or_plan, QueryGraph):
            gstats = stats or GraphStats.from_graph(self.graph)
            plan = optimal_plan(query_or_plan, gstats, self.cfg.num_machines, space)
        else:
            plan = query_or_plan
        return translate(plan)

    def prepare(
        self,
        query_or_plan: QueryGraph | ExecutionPlan | Dataflow,
        space: str = "huge",
        stats: GraphStats | None = None,
        session_stats: EngineStats | None = None,
        queue_capacity: int | None = None,
        join_buffer_capacity: int | None = None,
    ) -> EngineSession:
        """Build an execution session without running it. Multiple sessions
        can coexist on one engine — they share the graph arrays, the fetch
        caches, and the process-global jit cache, while each owns its
        slot-slice of device queues and its own stats (the multi-tenant
        substrate; see serve/graph_service.py)."""
        flow = self.to_flow(query_or_plan, space, stats)
        # Mandatory pre-flight (DESIGN.md §Static-analysis): a malformed flow
        # must fail here with structured diagnostics, not mid-run on device.
        # Imported lazily — analysis.flowcheck imports core.dataflow, and the
        # repro.core package itself imports this module.
        from repro.analysis.flowcheck import verify_flow

        verify_flow(flow, cfg=self.cfg, d_pad=self.d_pad,
                    queue_capacity=queue_capacity,
                    join_buffer_capacity=join_buffer_capacity)
        return EngineSession(
            self, flow, stats=session_stats,
            queue_capacity=queue_capacity,
            join_buffer_capacity=join_buffer_capacity,
        )

    def drive(self, session: EngineSession) -> EngineSession:
        """Run a session to completion under the graceful-degradation ladder
        (DESIGN.md §Fault-tolerance). On a recoverable fault the last
        checkpoint is restored — at half the batch with a DFS-biased
        scheduler for ``QueuePressure`` (drain before produce), unchanged for
        ``ShardLoss`` (enumeration is deterministic, so replay is exact) —
        and the run retries, up to ``cfg.max_retries`` times and never below
        ``cfg.min_batch_size``. Returns the session holding the final state
        (a *new* object when recovery restored). With ``cfg.recover`` off the
        session runs once and any fault propagates."""
        cfg = self.cfg
        if not cfg.recover:
            session.run()
            return session
        ckpt_steps = cfg.checkpoint_every_steps
        snap = session.snapshot()
        retries = 0
        while True:
            try:
                if ckpt_steps > 0:
                    while not session.done():
                        session.tick(ckpt_steps)
                        snap = session.snapshot()
                else:
                    session.run()
                return session
            except EnumerationFault as f:
                if not f.recoverable or retries >= cfg.max_retries:
                    raise
                retries += 1
                prev_batch = snap["batch_size"]
                if isinstance(f, ShardLoss):
                    new_batch = prev_batch
                else:
                    new_batch = max(prev_batch // 2, cfg.min_batch_size)
                    if new_batch >= prev_batch:
                        raise EnumerationFault(
                            f.kind,
                            "recovery ladder exhausted: batch already at "
                            f"floor {prev_batch} "
                            "(raise queue capacities or min_batch_size)",
                            op=f.op, query=f.query,
                        ) from f
                _log.warning(
                    "recovering from %s (attempt %d/%d): batch %d -> %d",
                    f, retries, cfg.max_retries, prev_batch, new_batch,
                )
                session = EngineSession.restore(
                    self, session.flow, snap, stats=session.stats,
                    batch_size=new_batch,
                    dfs_bias=not isinstance(f, ShardLoss),
                )
                self._queues = session.queues
                # Counters go up *after* the restore rolled stats back to the
                # snapshot, so recovery history survives the rollback.
                session.stats.retries += 1
                if isinstance(f, ShardLoss):
                    session.stats.restarts += 1
                else:
                    session.stats.pressure_events += 1
                snap = session.snapshot()

    def run(
        self,
        query_or_plan: QueryGraph | ExecutionPlan | Dataflow,
        space: str = "huge",
        stats: GraphStats | None = None,
    ) -> EnumerationResult:
        t_start = time.perf_counter()
        session = self.prepare(query_or_plan, space, stats, session_stats=self.stats)
        self._queues = session.queues  # keeps _memory_probe over the live run
        session = self.drive(session)
        result = session.result()
        self.stats.wall_time = time.perf_counter() - t_start
        self.stats.per_machine_rows = self.balance_rows.copy()
        return result


def enumerate_query(
    graph: Graph,
    query: QueryGraph,
    cfg: EngineConfig | None = None,
    space: str = "huge",
) -> EnumerationResult:
    """One-call API: plan, translate, schedule, execute, count."""
    return HugeEngine(graph, cfg).run(query, space=space)
