"""The paper's contribution, as composable JAX modules.

Plan layer:   query, plan, cost, optimizer (Alg. 1), dataflow (Alg. 2)
Engine layer: operators, cache (LRBU, Alg. 3/4), scheduler (Alg. 5),
              engine (single-process + comm accounting),
              distributed (shard_map SPMD engine — full scan/extend/verify/
              join DAGs with real collectives, incl. the PUSH-JOIN shuffle)
LM bridges:   hybrid_comm (Eq. 3 for MoE/vocab joins),
              adaptive_schedule (Alg. 5 for training microbatches)
Applications: paths (paper §6: shortest / hop-constrained paths)
"""
from repro.core.engine import EngineConfig, HugeEngine, enumerate_query
from repro.core.optimizer import optimal_plan
from repro.core.dataflow import translate
from repro.core.query import PAPER_QUERIES, QueryGraph

__all__ = [
    "EngineConfig", "HugeEngine", "enumerate_query",
    "optimal_plan", "translate", "PAPER_QUERIES", "QueryGraph",
]
