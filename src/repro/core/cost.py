"""Cardinality estimation for the optimiser (paper Alg. 1 line 4, refs [45,50]).

We estimate ``|R(q')|`` (number of monomorphisms of the sub-query in the data
graph) with a degree-moment (Chung-Lu configuration model) formula:

    |R(q')|  ≈  ( Π_{v ∈ V(q')}  S_{deg_{q'}(v)} )  /  (2|E_G|)^{|E(q')|}

where ``S_k = Σ_u d_G(u)^k`` are the degree moments of the data graph. For an
Erdős–Rényi graph this collapses to the classic ``V^n p^m``; for power-law
graphs the higher moments capture hub-driven blow-ups (stars are costed much
higher than paths, matching the paper's observation that RADS' star
materialisation explodes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np

from repro.core.plan import SubQuery, sub_vertices
from repro.graph.storage import Graph


@dataclasses.dataclass(frozen=True)
class GraphStats:
    num_vertices: int
    num_directed_edges: int  # 2|E|
    degree_moments: Dict[int, float]  # k -> S_k = sum(d^k)
    max_degree: int

    @staticmethod
    def from_graph(graph: Graph, max_k: int = 8) -> "GraphStats":
        deg = np.asarray(graph.padded.deg, dtype=np.float64)
        moments = {k: float(np.sum(deg**k)) for k in range(1, max_k + 1)}
        return GraphStats(
            num_vertices=graph.num_vertices,
            num_directed_edges=graph.num_directed_edges,
            degree_moments=moments,
            max_degree=int(deg.max()) if deg.size else 0,
        )

    @staticmethod
    def synthetic(num_vertices: int, avg_degree: float, exponent: float = 2.5, max_k: int = 8) -> "GraphStats":
        """Closed-form power-law moments for plan-time-only estimation."""
        ranks = np.arange(1, num_vertices + 1, dtype=np.float64)
        w = ranks ** (-1.0 / (exponent - 1.0))
        w *= (num_vertices * avg_degree) / w.sum()
        moments = {k: float(np.sum(w**k)) for k in range(1, max_k + 1)}
        return GraphStats(
            num_vertices=num_vertices,
            num_directed_edges=int(num_vertices * avg_degree),
            degree_moments=moments,
            max_degree=int(w.max()),
        )


class CardinalityEstimator:
    def __init__(self, stats: GraphStats):
        self.stats = stats

    def estimate(self, edges: SubQuery) -> float:
        verts = sub_vertices(edges)
        degs = {v: 0 for v in verts}
        for a, b in edges:
            degs[a] += 1
            degs[b] += 1
        num = 1.0
        for v in verts:
            k = degs[v]
            s_k = self.stats.degree_moments.get(k)
            if s_k is None:  # degree beyond precomputed moments: extrapolate
                s_k = self.stats.degree_moments[max(self.stats.degree_moments)] * (
                    float(self.stats.max_degree) ** (k - max(self.stats.degree_moments))
                )
            num *= s_k
        denom = float(self.stats.num_directed_edges) ** len(edges)
        est = num / max(denom, 1.0)
        return max(est, 1.0)

    def graph_edges(self) -> float:
        return float(self.stats.num_directed_edges) / 2.0
