"""Query graphs, automorphisms, and symmetry breaking.

Query graphs are tiny (≤ 8 vertices); everything here is host-side Python and
runs at plan time. Symmetry breaking follows Grochow-Kellis [27]: a set of
partial-order constraints ``ID(f(v_a)) < ID(f(v_b))`` such that exactly one
match per automorphism class of the query survives.

The paper's Figure 4 lists queries q1..q8 with their partial orders; the
figure itself is not reproduced in the text dump, so we adopt the standard
benchmark set of [46] (the codebase the paper builds from), which covers the
same structural spectrum: cycles, cliques, paths and their compositions.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, List, Sequence, Tuple

Edge = Tuple[int, int]


def _canon(e: Sequence[int]) -> Edge:
    a, b = int(e[0]), int(e[1])
    return (a, b) if a < b else (b, a)


@dataclasses.dataclass(frozen=True)
class QueryGraph:
    """An undirected, connected query graph over vertices 0..n-1."""

    num_vertices: int
    edges: FrozenSet[Edge]
    name: str = "query"

    @staticmethod
    def from_edges(edges: Sequence[Sequence[int]], name: str = "query") -> "QueryGraph":
        es = frozenset(_canon(e) for e in edges)
        n = max(max(e) for e in es) + 1
        return QueryGraph(num_vertices=n, edges=es, name=name)

    def adjacency(self) -> Dict[int, FrozenSet[int]]:
        adj: Dict[int, set] = {v: set() for v in range(self.num_vertices)}
        for a, b in self.edges:
            adj[a].add(b)
            adj[b].add(a)
        return {v: frozenset(s) for v, s in adj.items()}

    def degree(self, v: int) -> int:
        return sum(1 for e in self.edges if v in e)

    def has_edge(self, a: int, b: int) -> bool:
        return _canon((a, b)) in self.edges

    def automorphisms(self) -> List[Tuple[int, ...]]:
        """All permutations of V(q) preserving adjacency (n ≤ 8 → brute force)."""
        auts = []
        es = self.edges
        for perm in itertools.permutations(range(self.num_vertices)):
            if all(_canon((perm[a], perm[b])) in es for a, b in es):
                auts.append(perm)
        return auts

    def is_clique(self) -> bool:
        n = self.num_vertices
        return len(self.edges) == n * (n - 1) // 2

    def is_star(self) -> bool:
        root = self.star_root()
        return root is not None

    def star_root(self) -> int | None:
        """If the query is a star (tree of depth 1) return its root, else None."""
        n = self.num_vertices
        if len(self.edges) != n - 1:
            return None
        degs = [self.degree(v) for v in range(n)]
        if n == 2:
            return 0  # single edge: either endpoint roots it
        centers = [v for v in range(n) if degs[v] == n - 1]
        return centers[0] if centers else None


def symmetry_break(query: QueryGraph) -> List[Edge]:
    """Grochow-Kellis symmetry-breaking conditions.

    Returns a list of pairs (a, b) meaning the constraint ``f(a) < f(b)``.
    Iteratively: pick the smallest vertex with a non-trivial orbit, constrain
    it to be the minimum of its orbit, then restrict to its stabilizer.
    """
    conditions: List[Edge] = []
    auts = query.automorphisms()
    while len(auts) > 1:
        # Orbits under the current group.
        orbit_of: Dict[int, set] = {}
        for v in range(query.num_vertices):
            orbit_of[v] = {perm[v] for perm in auts}
        pivot = min(v for v in range(query.num_vertices) if len(orbit_of[v]) > 1)
        for u in sorted(orbit_of[pivot]):
            if u != pivot:
                conditions.append((pivot, u))
        auts = [perm for perm in auts if perm[pivot] == pivot]
    return conditions


# ---------------------------------------------------------------------------
# Benchmark query library (paper Figure 4 analogues).
# ---------------------------------------------------------------------------

def triangle() -> QueryGraph:
    return QueryGraph.from_edges([(0, 1), (1, 2), (0, 2)], name="triangle")


def square() -> QueryGraph:
    """q1 of the paper's running example (Table 1): the 4-cycle."""
    return QueryGraph.from_edges([(0, 1), (1, 2), (2, 3), (3, 0)], name="square")


def diamond() -> QueryGraph:
    """4-cycle + one chord."""
    return QueryGraph.from_edges([(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)], name="diamond")


def clique(k: int) -> QueryGraph:
    return QueryGraph.from_edges(
        [(i, j) for i in range(k) for j in range(i + 1, k)], name=f"{k}-clique"
    )


def path(k: int) -> QueryGraph:
    """k-vertex simple path."""
    return QueryGraph.from_edges([(i, i + 1) for i in range(k - 1)], name=f"{k}-path")


def star(k: int) -> QueryGraph:
    """k-leaf star (k+1 vertices)."""
    return QueryGraph.from_edges([(0, i) for i in range(1, k + 1)], name=f"{k}-star")


def house() -> QueryGraph:
    """Square with a triangle roof."""
    return QueryGraph.from_edges(
        [(0, 1), (1, 2), (2, 3), (3, 0), (0, 4), (1, 4)], name="house"
    )


def tailed_triangle() -> QueryGraph:
    return QueryGraph.from_edges([(0, 1), (1, 2), (0, 2), (2, 3)], name="tailed-triangle")


def double_square() -> QueryGraph:
    """Two squares sharing an edge (the 'ladder' on 6 vertices)."""
    return QueryGraph.from_edges(
        [(0, 1), (1, 2), (2, 3), (3, 0), (2, 4), (4, 5), (5, 3)], name="double-square"
    )


def chordal_square_tail() -> QueryGraph:
    """Diamond with a pendant — mixed-plan stressor (q8 analogue)."""
    return QueryGraph.from_edges(
        [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2), (1, 4)], name="chordal-square-tail"
    )


PAPER_QUERIES: Dict[str, QueryGraph] = {
    "q1": square(),
    "q2": diamond(),
    "q3": clique(4),
    "q4": house(),
    "q5": double_square(),
    "q6": clique(5),
    "q7": path(5),
    "q8": chordal_square_tail(),
}
