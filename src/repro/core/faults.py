"""Deterministic fault injection + the structured fault taxonomy.

HUGE's bounded-memory story (Theorem 5.4) and the multi-tenant service are
only safe if failure is a *modelled* state, not an accident: RADS (Ren et
al. 2019) made robustness-to-memory-pressure a design axis for distributed
subgraph enumeration, and G-thinker showed spill/recompute under pressure is
what lets these workloads survive real clusters. This module provides both
halves of that story (DESIGN.md §Fault-tolerance):

* a **taxonomy** of structured, attributable failures — every fault carries
  its kind, the operator label it fired at, and the query name, so a service
  log line identifies *which* tenant's *which* operator failed and whether
  the failure is recoverable (``QueuePressure``) or terminal;
* a **deterministic fault-injection harness** — a seeded :class:`FaultPlan`
  threaded through ``EngineConfig`` / ``DistConfig`` / ``ServiceConfig``
  that fires named fault kinds at specific operator invocations. The same
  ``(seed, specs)`` always fires at the same step of the same op, so every
  chaos-test failure replays exactly (``REPRO_FAULT_SEED`` sweeps move the
  trigger points across the schedule without losing determinism).

Fault kinds (the chaos matrix rows; see tests/test_chaos.py):

====================  =====================================================
``queue-overflow``    an operator output queue cannot absorb a batch
                      (Lemma 5.2 slack exhausted) — recoverable by halving
                      the batch and biasing the scheduler toward DFS
``join-overflow``     a PUSH-JOIN probe produced more rows than
                      ``join_out_capacity`` — recoverable the same way
                      (a smaller right batch bounds the probe output)
``kernel-fail``       a fused Pallas kernel failed to execute — recoverable
                      one-shot by falling back to the ``ref.py`` twin
``shard-loss``        a simulated machine/shard died mid-query — recoverable
                      by restoring the last checkpoint (single-process) or
                      deterministically re-executing the flow (SPMD)
``lease-oom``         the slot pool transiently refused a lease at
                      admission — recoverable by waiting for the next sweep
====================  =====================================================
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

FAULT_KINDS: Tuple[str, ...] = (
    "queue-overflow",
    "join-overflow",
    "kernel-fail",
    "shard-loss",
    "lease-oom",
)


# ---------------------------------------------------------------------------
# Structured failures
# ---------------------------------------------------------------------------

class EnumerationFault(RuntimeError):
    """A structured, attributable enumeration failure.

    ``kind`` names the failure class (one of :data:`FAULT_KINDS` for injected
    faults, or an organic kind such as ``queue-overflow`` raised by a real
    capacity breach); ``op`` is the failing operator's label and ``query``
    the dataflow's query name, so non-recoverable failures are attributable
    in service logs without a debugger. ``recoverable`` tells the recovery
    ladder whether retrying under degradation can help."""

    def __init__(self, kind: str, message: str, *, op: str = "?",
                 query: str = "?", recoverable: bool = False):
        self.kind = kind
        self.op = op
        self.query = query
        self.recoverable = recoverable
        self.session = None  # attached by _ScopedRT for service attribution
        super().__init__(f"[{kind}] op={op} query={query or '?'}: {message}")


class QueuePressure(EnumerationFault):
    """Recoverable memory-pressure signal: a queue (or join output buffer)
    could not absorb a batch. The Lemma 5.2 slack becomes a *soft* bound —
    the recovery ladder halves the batch, biases the adaptive scheduler
    toward DFS (drain before produce), and retries from the last
    checkpoint instead of crashing."""

    def __init__(self, kind: str, message: str, *, op: str = "?", query: str = "?"):
        super().__init__(kind, message, op=op, query=query, recoverable=True)


class KernelFault(EnumerationFault):
    """A fused Pallas kernel failed; the caller falls back one-shot to the
    pure-jnp ref twin for the affected batch (stat: ``kernel_fallbacks``)."""

    def __init__(self, message: str, *, op: str = "?", query: str = "?"):
        super().__init__("kernel-fail", message, op=op, query=query,
                         recoverable=True)


class ShardLoss(EnumerationFault):
    """A (simulated) machine/shard died mid-query. Enumeration is
    deterministic, so recovery is re-execution: restore the last checkpoint
    (single-process sessions) or rebuild the SPMD runtimes and re-run."""

    def __init__(self, shard: int, *, op: str = "?", query: str = "?"):
        self.shard = shard
        super().__init__("shard-loss", f"shard {shard} lost", op=op,
                         query=query, recoverable=True)


# ---------------------------------------------------------------------------
# Deterministic injection
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault: fire ``kind`` at the ``at_step``-th eligible
    invocation of an operator whose label contains ``op`` (``"*"`` matches
    any op). ``at_step=None`` derives the step from the plan seed, so a
    seed sweep moves the trigger across the schedule deterministically.
    ``times`` bounds how often the spec fires (default one-shot, so a
    recovered retry does not re-trip the same fault forever)."""

    kind: str
    op: str = "*"
    at_step: Optional[int] = None
    times: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}"
            )


class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    ``should_fire(kind, op)`` is the single probe the engines call at every
    injection point; it counts eligible invocations per spec and returns
    True exactly when a spec's trigger step is reached (and its ``times``
    budget is not exhausted). All state is host-side counters — nothing
    about injection touches traced code, so jit caches are fault-agnostic.
    ``fired`` records every fired event for assertions and stats."""

    def __init__(self, specs: Tuple[FaultSpec, ...] | List[FaultSpec] = (),
                 seed: int = 0):
        self.seed = int(seed)
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self._seen: Dict[int, int] = {i: 0 for i in range(len(self.specs))}
        self._hits: Dict[int, int] = {i: 0 for i in range(len(self.specs))}
        self.fired: List[Tuple[str, str, int]] = []  # (kind, op, step)

    # -- construction --------------------------------------------------------

    @classmethod
    def single(cls, kind: str, op: str = "*", at_step: Optional[int] = None,
               seed: int = 0, times: int = 1) -> "FaultPlan":
        return cls((FaultSpec(kind, op, at_step, times),), seed=seed)

    @classmethod
    def from_env(cls, env=None) -> Optional["FaultPlan"]:
        """Build a plan from ``REPRO_FAULT_KIND`` / ``REPRO_FAULT_SEED`` /
        ``REPRO_FAULT_OP`` / ``REPRO_FAULT_STEP`` — the CI chaos job's
        interface. Returns None when no kind is requested."""
        env = os.environ if env is None else env
        kind = env.get("REPRO_FAULT_KIND", "")
        if not kind:
            return None
        step = env.get("REPRO_FAULT_STEP", "")
        return cls.single(
            kind,
            op=env.get("REPRO_FAULT_OP", "*"),
            at_step=int(step) if step else None,
            seed=int(env.get("REPRO_FAULT_SEED", "0")),
        )

    # -- probing -------------------------------------------------------------

    def _trigger_step(self, i: int) -> int:
        spec = self.specs[i]
        if spec.at_step is not None:
            return spec.at_step
        # Seed-derived trigger: a small deterministic hash spreads different
        # (seed, spec) pairs over the first few invocations of the op, so a
        # REPRO_FAULT_SEED sweep exercises early/mid-schedule triggers.
        h = self.seed * 1000003 + i * 10007 + len(spec.kind) * 101
        return (h ^ (h >> 7)) % 6

    def should_fire(self, kind: str, op: str) -> bool:
        fired = False
        for i, spec in enumerate(self.specs):
            if spec.kind != kind:
                continue
            if spec.op != "*" and spec.op.lower() not in op.lower():
                continue  # case-insensitive: labels are uppercase (SCAN/EXT…)
            step = self._seen[i]
            self._seen[i] = step + 1
            if self._hits[i] < spec.times and step >= self._trigger_step(i):
                self._hits[i] += 1
                self.fired.append((kind, op, step))
                fired = True
        return fired

    def fired_count(self, kind: Optional[str] = None) -> int:
        if kind is None:
            return len(self.fired)
        return sum(1 for k, _, _ in self.fired if k == kind)

    def reset(self) -> None:
        """Forget all counters (a fresh run under the same plan)."""
        self._seen = {i: 0 for i in range(len(self.specs))}
        self._hits = {i: 0 for i in range(len(self.specs))}
        self.fired = []
