"""Algorithm 1: OptimalExecutionPlan — DP over connected sub-queries.

The optimiser minimises computation + communication cost over the plan space:

  cost(q')  =  cost(q'_l) + cost(q'_r) + |R(q')| + comm(q', q'_l, q'_r)
  comm      =  k·|E_G|                      if Eq. 3 assigns pulling
            =  |R(q'_l)| + |R(q'_r)|        otherwise (shuffle both sides)

Sub-queries are encoded as bitmasks over the query's edge list so the DP can
enumerate every edge-disjoint decomposition ``q' = q'_l ∪ q'_r`` with the
sub-mask trick (total work Σ 3^{|E_q|}, fine for ≤ 15-edge queries).

Plan spaces (Table 2) constrain: allowed join units, left-deep vs bushy,
allowed join algorithms/communication modes (see plan.PlanSpace). This single
optimiser therefore produces HUGE's plans *and* the plug-in logical plans of
StarJoin / SEED / BiGJoin / BENU / RADS used by Exp-1/Exp-9.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.core.cost import CardinalityEstimator, GraphStats
from repro.core.plan import (
    ExecutionPlan,
    PlanNode,
    PlanSpace,
    PLAN_SPACES,
    SubQuery,
    assign_physical,
    is_clique_sub,
    is_complete_star_join,
    is_connected,
    star_of,
    sub_vertices,
)
from repro.core.query import QueryGraph, symmetry_break


@dataclasses.dataclass
class _Entry:
    cost: float
    split: Optional[Tuple[int, int]]  # (left_mask, right_mask) or None for a unit


def _mask_edges(mask: int, edge_list: List[Tuple[int, int]]) -> SubQuery:
    return frozenset(e for i, e in enumerate(edge_list) if mask >> i & 1)


def _is_unit(edges: SubQuery, space: PlanSpace) -> bool:
    if space.unit_max_edges is not None and len(edges) > space.unit_max_edges:
        return False
    if "star" in space.units and star_of(edges) is not None:
        return True
    if "clique" in space.units and is_clique_sub(edges):
        return True
    return False


class Optimizer:
    """Paper Algorithm 1, parameterised by a Table-2 plan space."""

    def __init__(self, stats: GraphStats, num_machines: int = 1, space: PlanSpace | str = "huge"):
        self.estimator = CardinalityEstimator(stats)
        self.k = max(1, num_machines)
        self.space = PLAN_SPACES[space] if isinstance(space, str) else space

    # -- cost pieces ---------------------------------------------------------

    def _comm_cost(self, left: SubQuery, right: SubQuery, algo: str, comm: str) -> float:
        if comm == "pull":
            # Remark 3.1: at most the whole data graph per machine.
            return self.k * self.estimator.graph_edges()
        if algo == "wco":
            # push wco: stream R(l) to each leaf owner: ~ d_avg * |R(l)|
            davg = self.estimator.stats.num_directed_edges / max(1, self.estimator.stats.num_vertices)
            return davg * self.estimator.estimate(left)
        return self.estimator.estimate(left) + self.estimator.estimate(right)

    # -- DP ------------------------------------------------------------------

    def plan(self, query: QueryGraph) -> ExecutionPlan:
        edge_list = sorted(query.edges)
        m = len(edge_list)
        full = (1 << m) - 1

        est_cache: Dict[int, float] = {}

        def est(mask: int) -> float:
            if mask not in est_cache:
                est_cache[mask] = self.estimator.estimate(_mask_edges(mask, edge_list))
            return est_cache[mask]

        conn_cache: Dict[int, bool] = {}

        def connected(mask: int) -> bool:
            if mask not in conn_cache:
                conn_cache[mask] = is_connected(_mask_edges(mask, edge_list))
            return conn_cache[mask]

        table: Dict[int, _Entry] = {}

        def solve(mask: int) -> Optional[_Entry]:
            """Best cost to *produce* R(sub-query mask); None if infeasible."""
            if mask in table:
                return table[mask]
            edges = _mask_edges(mask, edge_list)
            if not connected(mask):
                table[mask] = None
                return None
            best: Optional[_Entry] = None
            if _is_unit(edges, self.space):
                best = _Entry(cost=est(mask), split=None)
            # Try every edge-disjoint decomposition (sub-mask enumeration).
            # Skip if the space only has units and this IS a unit (paper line 4
            # returns early for units — decompositions of units never win
            # because any split adds |R(q')| again; keep the early-out).
            if best is None or not _is_unit(edges, self.space):
                sub = (mask - 1) & mask
                seen = set()
                while sub > 0:
                    l_mask, r_mask = sub, mask ^ sub
                    key = min(l_mask, r_mask)
                    if key not in seen and l_mask and r_mask:
                        seen.add(key)
                        cand = self._try_join(mask, l_mask, r_mask, edge_list, solve, est)
                        if cand is not None and (best is None or cand.cost < best.cost):
                            best = cand
                    sub = (sub - 1) & mask
            table[mask] = best
            return best

        entry = solve(full)
        if entry is None:
            raise ValueError(f"no feasible plan for {query.name} in space {self.space.name}")

        root = self._recover(full, edge_list, table)
        return ExecutionPlan(
            query=query,
            root=root,
            symmetry_conditions=tuple(symmetry_break(query)),
            est_cost=entry.cost,
        )

    def _try_join(self, mask, l_mask, r_mask, edge_list, solve, est) -> Optional[_Entry]:
        left = _mask_edges(l_mask, edge_list)
        right = _mask_edges(r_mask, edge_list)
        # Joined sides must share at least one vertex (join key non-empty).
        if not (sub_vertices(left) & sub_vertices(right)):
            return None
        best: Optional[_Entry] = None
        for a_mask, b_mask, a_edges, b_edges in ((l_mask, r_mask, left, right), (r_mask, l_mask, right, left)):
            if self.space.complete_star_only and is_complete_star_join(a_edges, b_edges) is None:
                continue
            algo, comm = assign_physical(a_edges, b_edges, self.space)
            if algo not in self.space.algos or comm not in self.space.comms:
                continue
            # left-deep: the rhs must be a *scannable* unit — except for wco
            # joins, whose star side is virtual (never materialised), so the
            # unit_max_edges scan restriction doesn't apply to it.
            if self.space.order == "leftdeep" and algo != "wco" and not _is_unit(b_edges, self.space):
                continue
            if algo == "wco" and star_of(b_edges) is None:
                continue
            ea = solve(a_mask)
            if ea is None:
                continue
            if algo == "wco":
                # A wco join never materialises its star side (that is its
                # worst-case-optimality).
                rb_cost = 0.0
            else:
                eb = solve(b_mask)
                if eb is None:
                    continue
                rb_cost = eb.cost
            c = ea.cost + rb_cost + est(a_mask | b_mask) + self._comm_cost(a_edges, b_edges, algo, comm)
            if best is None or c < best.cost:
                best = _Entry(cost=c, split=(a_mask, b_mask))
        return best

    def _recover(self, mask: int, edge_list, table) -> PlanNode:
        entry = table[mask]
        edges = _mask_edges(mask, edge_list)
        if entry.split is None:
            return PlanNode(edges=edges)
        l_mask, r_mask = entry.split
        l_edges = _mask_edges(l_mask, edge_list)
        r_edges = _mask_edges(r_mask, edge_list)
        algo, comm = assign_physical(l_edges, r_edges, self.space)
        left = self._recover(l_mask, edge_list, table)
        if algo == "wco":
            right = PlanNode(edges=r_edges)  # star side is never materialised
        else:
            right = self._recover(r_mask, edge_list, table)
        return PlanNode(edges=edges, left=left, right=right, algo=algo, comm=comm)


def optimal_plan(
    query: QueryGraph,
    stats: GraphStats,
    num_machines: int = 1,
    space: PlanSpace | str = "huge",
) -> ExecutionPlan:
    return Optimizer(stats, num_machines, space).plan(query)
