"""BFS/DFS-adaptive scheduler — paper Algorithm 5 (§5.2), generalised to DAGs.

Each operator owns a fixed-capacity output queue. The scheduler lets the
current operator consume as many input batches as possible (BFS-style, max
parallelism) but *yields* it the moment its output queue cannot absorb another
batch's worst-case results, scheduling the successor instead; when an operator
drains its input the scheduler backtracks to the precursor. Queue capacities
are preallocated device arrays, so the paper's O(|V_q|²·D_G) bound becomes a
structural compile-time constant.

The scheduler works over an abstract runtime interface so the same loop
drives SCAN / PULL-EXTEND / VERIFY / PUSH-JOIN dataflows in both the
single-process engine (engine.py) and the distributed shard_map engine
(distributed.py).

Operator *DAGs* (plans with PUSH-JOIN barriers) are scheduled as their
topological order (Dataflow emission order): every producer precedes its
consumers, so "backtrack to the precursor" is simply "move left". A
multi-input operator such as PUSH-JOIN participates through the same
four-method protocol — its ``has_input`` consults *both* upstream queues (and
its barrier condition: probing only once the buffered branch has drained, see
DESIGN.md §Shuffle-join), so the scheduler itself stays oblivious to arity.
Termination is unchanged: the loop exits when no operator reports input,
and a barrier op always eventually unblocks because its upstream branch
strictly precedes it in the order.
"""
from __future__ import annotations

import dataclasses
from typing import List, Protocol


class OperatorRuntime(Protocol):
    label: str

    def has_input(self) -> bool: ...
    def output_free(self) -> int: ...
    def required_slack(self) -> int: ...
    def run_one(self) -> None: ...


@dataclasses.dataclass
class ScheduleStats:
    steps: int = 0
    yields_full: int = 0
    yields_empty: int = 0
    backtracks: int = 0
    peak_queue_rows: int = 0
    peak_queue_bytes: int = 0
    completed: bool = True  # False only when run(max_steps=...) hit its budget

    def merge(self, other: "ScheduleStats") -> "ScheduleStats":
        """Accumulate another pass's counters (used by tick-driven callers
        that build one scheduler pass per service tick)."""
        self.steps += other.steps
        self.yields_full += other.yields_full
        self.yields_empty += other.yields_empty
        self.backtracks += other.backtracks
        self.peak_queue_rows = max(self.peak_queue_rows, other.peak_queue_rows)
        self.peak_queue_bytes = max(self.peak_queue_bytes, other.peak_queue_bytes)
        self.completed = other.completed
        return self


class AdaptiveScheduler:
    """Algorithm 5 over a topologically ordered operator list (chain or DAG).

    The paper's literal pseudocode bounces precursor↔successor when the head
    of the chain is exhausted; we resolve direction by whether *any* upstream
    operator still has input (identical schedule on live inputs, guaranteed
    termination on drained ones). For DAGs, "upstream" means "earlier in the
    topological order" — a superset of the true ancestors, which only makes
    the liveness check conservative, never wrong.
    """

    def __init__(self, chain: List[OperatorRuntime], memory_probe=None,
                 dfs_bias: bool = False):
        self.chain = chain
        self.memory_probe = memory_probe  # () -> (rows, bytes)
        self.dfs_bias = dfs_bias  # one batch per visit: drain downstream
        #   before producing more (the recovery ladder's memory-pressure mode,
        #   DESIGN.md §Fault-tolerance)
        self.stats = ScheduleStats()

    def _probe(self):
        if self.memory_probe is not None:
            rows, nbytes = self.memory_probe()
            self.stats.peak_queue_rows = max(self.stats.peak_queue_rows, rows)
            self.stats.peak_queue_bytes = max(self.stats.peak_queue_bytes, nbytes)

    def run(self, max_steps: int | None = None) -> ScheduleStats:
        """Drive the chain until every operator drains, or — when ``max_steps``
        is given — until that many ``run_one`` calls have executed. A budgeted
        return sets ``stats.completed = False`` so tick-driven callers (the
        multi-tenant graph service) know work remains; calling ``run`` again
        on a fresh scheduler over the same runtimes resumes exactly where the
        queues left off (all scheduling state lives in the queues/cursors)."""
        chain = self.chain
        last = len(chain) - 1
        cur = 0
        stall = 0  # iterations since the last batch ran (deadlock guard)
        budget = max_steps if max_steps is not None else -1
        while True:
            if budget == 0:
                self.stats.completed = False
                return self.stats
            if stall > 4 * len(chain) + 8:
                raise RuntimeError(
                    "scheduler stalled: every operator is blocked on a full "
                    "output queue — raise queue/join-buffer capacity "
                    f"(chain: {[op.label for op in chain]})"
                )
            op = chain[cur]
            if op.has_input():
                # Schedule(O): consume until the output queue can no longer
                # absorb a worst-case batch, or the input drains.
                ran = False
                while op.has_input() and op.output_free() >= op.required_slack():
                    op.run_one()
                    ran = True
                    self.stats.steps += 1
                    self._probe()
                    if budget > 0:
                        budget -= 1
                        if budget == 0:
                            self.stats.completed = False
                            return self.stats
                    if self.dfs_bias:
                        # Memory-pressure mode: emit one batch, then move on
                        # so downstream ops drain it before more is produced.
                        break
                stall = 0 if ran else stall + 1
                if op.has_input():
                    self.stats.yields_full += 1  # yielded on full queue
                else:
                    self.stats.yields_empty += 1
                if cur == last:
                    self.stats.backtracks += 1
                    cur = max(cur - 1, 0)
                else:
                    cur += 1
                continue
            # O has no input: backtrack to the nearest upstream op that can
            # actually *run* (has input and output room), jumping over blocked
            # and drained ones. Stepping back one at a time would strand the
            # cursor against a blocked multi-input op — it has input, so it
            # bounces the cursor forward again, and runnable work further
            # upstream is never reached. An upstream op that is merely blocked
            # is no reason to stop: in a DAG its relief (the consumer of its
            # full queue) lies *downstream*, so prefer advancing when anything
            # later is live. (On a linear chain the op downstream of a blocked
            # op always has input, so neither situation arises and the
            # schedule is unchanged.)
            stall += 1
            up_run = next(
                (
                    j for j in range(cur - 1, -1, -1)
                    if chain[j].has_input()
                    and chain[j].output_free() >= chain[j].required_slack()
                ),
                None,
            )
            down_live = any(chain[j].has_input() for j in range(cur + 1, len(chain)))
            if up_run is not None:
                self.stats.backtracks += 1
                cur = up_run
            elif down_live:
                cur += 1
            elif any(chain[j].has_input() for j in range(cur)):
                self.stats.backtracks += 1
                cur -= 1  # only blocked work left upstream: let the stall
                          # guard prove it a real deadlock
            else:
                break  # every operator drained → dataflow complete
        return self.stats
