"""BFS/DFS-adaptive scheduler — paper Algorithm 5 (§5.2).

Each operator owns a fixed-capacity output queue. The scheduler lets the
current operator consume as many input batches as possible (BFS-style, max
parallelism) but *yields* it the moment its output queue cannot absorb another
batch's worst-case results, scheduling the successor instead; when an operator
drains its input the scheduler backtracks to the precursor. Queue capacities
are preallocated device arrays, so the paper's O(|V_q|²·D_G) bound becomes a
structural compile-time constant.

The scheduler works over an abstract runtime interface so the same loop
drives SCAN / PULL-EXTEND / VERIFY / PUSH-JOIN chains (engine.py) and the
distributed shard_map engine (distributed.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Protocol


class OperatorRuntime(Protocol):
    label: str

    def has_input(self) -> bool: ...
    def output_free(self) -> int: ...
    def required_slack(self) -> int: ...
    def run_one(self) -> None: ...


@dataclasses.dataclass
class ScheduleStats:
    steps: int = 0
    yields_full: int = 0
    yields_empty: int = 0
    backtracks: int = 0
    peak_queue_rows: int = 0
    peak_queue_bytes: int = 0


class AdaptiveScheduler:
    """Algorithm 5 over a linear operator chain.

    The paper's literal pseudocode bounces precursor↔successor when the head
    of the chain is exhausted; we resolve direction by whether *any* upstream
    operator still has input (identical schedule on live inputs, guaranteed
    termination on drained ones).
    """

    def __init__(self, chain: List[OperatorRuntime], memory_probe=None):
        self.chain = chain
        self.memory_probe = memory_probe  # () -> (rows, bytes)
        self.stats = ScheduleStats()

    def _probe(self):
        if self.memory_probe is not None:
            rows, nbytes = self.memory_probe()
            self.stats.peak_queue_rows = max(self.stats.peak_queue_rows, rows)
            self.stats.peak_queue_bytes = max(self.stats.peak_queue_bytes, nbytes)

    def run(self) -> ScheduleStats:
        chain = self.chain
        last = len(chain) - 1
        cur = 0
        stall = 0  # iterations since the last batch ran (deadlock guard)
        while True:
            if stall > 4 * len(chain) + 8:
                raise RuntimeError(
                    "scheduler stalled: every operator is blocked on a full "
                    "output queue — raise queue/join-buffer capacity "
                    f"(chain: {[op.label for op in chain]})"
                )
            op = chain[cur]
            if op.has_input():
                # Schedule(O): consume until the output queue can no longer
                # absorb a worst-case batch, or the input drains.
                ran = False
                while op.has_input() and op.output_free() >= op.required_slack():
                    op.run_one()
                    ran = True
                    self.stats.steps += 1
                    self._probe()
                stall = 0 if ran else stall + 1
                if op.has_input():
                    self.stats.yields_full += 1  # yielded on full queue
                else:
                    self.stats.yields_empty += 1
                if cur == last:
                    self.stats.backtracks += 1
                    cur = max(cur - 1, 0)
                else:
                    cur += 1
                continue
            # O has no input: backtrack if upstream work exists, else advance.
            stall += 1
            if any(chain[j].has_input() for j in range(cur)):
                self.stats.backtracks += 1
                cur -= 1
            elif any(chain[j].has_input() for j in range(cur + 1, len(chain))):
                cur += 1
            else:
                break  # every operator drained → chain complete
        return self.stats
