"""HUGE's push/pull-hybrid communication rule applied to LM layers.

The paper's core physical-planning insight (Property 3.1 / Remark 3.1 /
Eq. 3): for each distributed join, either *push* the intermediate results
(shuffle R(q'_l), R(q'_r)) or *pull* the operand data (≤ k·|E_G|), whichever
moves fewer bytes. In an LM the same choice appears wherever a sharded
contraction pairs a large weight with routed activations:

  * MoE dispatch — push = all_to_all the routed tokens to the expert shards
    (the hash-join shuffle: tokens keyed by expert id); pull = all-gather the
    expert weights to the token shards (the PULL-EXTEND: fetch operand data,
    compute locally).
  * Vocab projection — push = shuffle per-shard logits; pull = gather the
    embedding rows of the batch's tokens.

This module is the Alg.-1-style optimiser for those joins: a byte-cost model
per communication mode, and a decision function the layers consult at trace
time. The decision is static per (arch × shape) — exactly like the paper's
plan-time physical configuration — so XLA sees a fixed collective schedule.

``enum_join_mode`` is the same Eq.-3 rule for the paper's native workload: a
distributed subgraph-enumeration join, where push = the PUSH-JOIN hash
shuffle of both intermediate result sets (distributed.py executes it with
the same dense ``all_to_all`` machinery as the fetch stage) and pull = the
k·|E_G| operand-fetch bound of Remark 3.1.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CommDecision:
    mode: str          # "push" | "pull"
    push_bytes: float  # bytes moved per step if pushing
    pull_bytes: float  # bytes moved per step if pulling
    reason: str

    @property
    def ratio(self) -> float:
        return self.push_bytes / max(self.pull_bytes, 1.0)


def enum_join_mode(
    *,
    left_rows: float,       # |R(q'_l)| partial matches entering the join
    right_rows: float,      # |R(q'_r)|
    width_left: int,        # row width (matched vertices) per side
    width_right: int,
    graph_edges: float,     # |E_G| (undirected)
    machines: int,
    bytes_per_elem: int = 4,
) -> CommDecision:
    """Eq. 3 for a distributed subgraph-enumeration join (Property 3.1).

    push: shuffle both intermediate result sets by join key — (k−1)/k of the
          rows cross the network (the PUSH-JOIN hash-a2a of distributed.py).
    pull: fetch operand adjacency on demand, bounded by k·|E_G| edge records
          (Remark 3.1 — each machine pulls at most the whole data graph).

    This is the decision the optimiser's ``_comm_cost`` applies at plan time;
    exposed here so benchmarks/exp_dist_hybrid.py can print the model's
    prediction next to the traffic the collectives actually moved.
    """
    frac = (machines - 1) / max(1, machines)
    push = (left_rows * width_left + right_rows * width_right) * bytes_per_elem * frac
    pull = machines * graph_edges * 2 * bytes_per_elem
    mode = "push" if push <= pull else "pull"
    return CommDecision(
        mode=mode, push_bytes=push, pull_bytes=pull,
        reason=(
            f"|R_l|·w_l+|R_r|·w_r={left_rows:.3g}·{width_left}+"
            f"{right_rows:.3g}·{width_right} vs k·|E_G|={machines}·{graph_edges:.3g}"
        ),
    )


def moe_dispatch_mode(
    *,
    tokens_per_step: int,      # tokens entering this layer per optimizer step
    d_model: int,
    d_ff: int,
    num_experts: int,
    experts_per_token: int,
    dp_degree: int,            # shards the experts are spread over (EP axis)
    bytes_per_elem: int = 2,
    backward: bool = True,
) -> CommDecision:
    """Eq.-3 analogue for one MoE layer.

    push: each routed token crosses the EP axis twice (dispatch + combine),
          and the backward pass mirrors it:  4·T·topk·d_model·(dp-1)/dp bytes.
    pull: every expert's weights are gathered to all shards once per step
          (3 matrices, fwd + grad reduce):  ~4·E·d_model·d_ff·(dp-1)/dp.
    Mirrors Remark 3.1: intermediate results (routed activations) vs data
    graph (weights) — pulling the *fixed-size* weights wins exactly when the
    routed-token volume exceeds them (big training batches through small
    experts); pushing wins for tiny decode batches.
    """
    frac = (dp_degree - 1) / max(1, dp_degree)
    trips = 4 if backward else 2
    push = trips * tokens_per_step * experts_per_token * d_model * bytes_per_elem * frac
    wtrips = 4 if backward else 1
    pull = wtrips * num_experts * 3 * d_model * d_ff * bytes_per_elem * frac
    mode = "push" if push <= pull else "pull"
    return CommDecision(
        mode=mode, push_bytes=push, pull_bytes=pull,
        reason=(
            f"tokens·topk·d={tokens_per_step}·{experts_per_token}·{d_model} vs "
            f"E·3·d·ff={num_experts}·3·{d_model}·{d_ff}"
        ),
    )


def vocab_mode(
    *,
    tokens_per_step: int,
    d_model: int,
    vocab_size: int,
    tp_degree: int,
    bytes_per_elem: int = 2,
) -> CommDecision:
    """Vocab projection: push = reduce logits over the TP axis
    (T·V/tp... we count the reduce-scatter of the V-sharded logits wins:
    T·d bytes per shard boundary), pull = gather weight columns. For the big
    256k vocabs the logits dominate at prefill and the weights at decode."""
    frac = (tp_degree - 1) / max(1, tp_degree)
    push = 2 * tokens_per_step * d_model * bytes_per_elem * frac  # psum of [T, d] grads + fwd
    pull = vocab_size * d_model * bytes_per_elem * frac / max(1, tp_degree)
    mode = "push" if push <= pull else "pull"
    return CommDecision(mode=mode, push_bytes=push, pull_bytes=pull,
                        reason=f"T·d={tokens_per_step}·{d_model} vs V·d/tp")
