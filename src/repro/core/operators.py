"""Vectorised JAX implementations of the primitive operators (paper §4.2-4.4).

Batches of partial matches are dense int32 arrays ``rows[B, K]`` with a valid
count ``n`` (rows ≥ n are ignored; INVALID-filled). Queues are fixed-capacity
stacks ``(buf[CAP, K], n)`` — enumeration has set semantics so LIFO order is
irrelevant, and stack pops are cheap dynamic slices.

All functions are pure and jit-compiled with static shape arguments; the
BFS/DFS-adaptive scheduler (scheduler.py) drives them batch-by-batch exactly
as Algorithm 5 prescribes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.graph.storage import INVALID


# ---------------------------------------------------------------------------
# Small utilities
# ---------------------------------------------------------------------------

def row_membership(sorted_rows: jax.Array, queries: jax.Array) -> jax.Array:
    """queries[b, j] ∈ sorted_rows[b, :] (rows sorted ascending, INVALID-padded)."""
    idx = jax.vmap(jnp.searchsorted)(sorted_rows, queries)
    idx = jnp.clip(idx, 0, sorted_rows.shape[-1] - 1)
    found = jnp.take_along_axis(sorted_rows, idx, axis=-1)
    return (found == queries) & (queries != INVALID)


def compact(rows: jax.Array, mask: jax.Array, out_cap: int) -> Tuple[jax.Array, jax.Array]:
    """Pack masked rows to the front of a fresh [out_cap, K] buffer."""
    k = rows.shape[-1]
    pos = jnp.cumsum(mask) - 1
    n = jnp.sum(mask, dtype=jnp.int32)
    tgt = jnp.where(mask, pos, out_cap)  # out-of-range → dropped by scatter
    out = jnp.full((out_cap, k), INVALID, dtype=jnp.int32)
    out = out.at[tgt].set(rows, mode="drop")
    return out, n


@jax.jit
def dedup_pad(vids: jax.Array) -> jax.Array:
    """Unique valid vertex ids packed to the front, INVALID-padded to the input
    length (the merged-RPC dedup; also the precondition of the LRBU value-cache
    insert, whose scatters would race on duplicate keys)."""
    n = vids.shape[0]
    v = jnp.where((vids >= 0) & (vids != INVALID), vids, INVALID)
    s = jnp.sort(v)
    keep = (s != INVALID) & jnp.concatenate(
        [jnp.ones((1,), bool), s[1:] != s[:-1]]
    )
    pos = jnp.cumsum(keep) - 1
    tgt = jnp.where(keep, pos, n)
    return jnp.full((n,), INVALID, jnp.int32).at[tgt].set(s, mode="drop")


def lexsort_rows(cols: jax.Array) -> jax.Array:
    """Stable lexicographic argsort by columns of ``cols[N, C]`` (col 0 primary)."""
    n = cols.shape[0]
    order = jnp.arange(n, dtype=jnp.int32)
    for c in range(cols.shape[1] - 1, -1, -1):
        vals = jnp.take(cols[:, c], order)
        perm = jnp.argsort(vals, stable=True)
        order = jnp.take(order, perm)
    return order


# ---------------------------------------------------------------------------
# Queue (fixed-capacity stack)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def queue_append(buf: jax.Array, n: jax.Array, rows: jax.Array, m: jax.Array):
    cap = buf.shape[0]
    r = rows.shape[0]
    idx = n + jnp.arange(r, dtype=jnp.int32)
    tgt = jnp.where(jnp.arange(r) < m, idx, cap)
    buf = buf.at[tgt].set(rows, mode="drop")
    return buf, jnp.minimum(n + m, cap)


@functools.partial(jax.jit, static_argnames=("batch",))
def queue_pop(buf: jax.Array, n: jax.Array, batch: int):
    take = jnp.minimum(n, batch).astype(jnp.int32)
    start = jnp.maximum(n - take, 0)
    rows = lax.dynamic_slice(buf, (start, jnp.int32(0)), (batch, buf.shape[1]))
    return rows, take, n - take


@functools.partial(jax.jit, static_argnames=("num_shards",))
def partition_rows_by_key(rows: jax.Array, valid: jax.Array, key: jax.Array,
                          num_shards: int) -> jax.Array:
    """Group rows by destination shard ``key % num_shards`` for an all_to_all.

    Returns ``send[P, B, K]`` (INVALID-padded): ``send[d]`` holds the rows
    destined to shard ``d``, packed to the front. This is the send tensor of
    the PUSH-JOIN hash shuffle (DESIGN.md §Shuffle-join) — the collective
    itself lives in distributed.py; this part is pure and unit-testable.
    """
    b, k = rows.shape
    dest = jnp.where(valid, key % num_shards, num_shards)
    order = jnp.argsort(dest, stable=True)
    sdest = jnp.take(dest, order)
    srows = jnp.take(rows, order, axis=0)
    cnt = jax.ops.segment_sum(
        (sdest < num_shards).astype(jnp.int32), sdest, num_segments=num_shards + 1
    )[:num_shards]
    offs = jnp.cumsum(cnt) - cnt
    offs_ext = jnp.concatenate([offs, jnp.zeros((1,), jnp.int32)])
    slot = jnp.arange(b, dtype=jnp.int32) - jnp.take(
        offs_ext, jnp.minimum(sdest, num_shards)
    )
    ok = sdest < num_shards
    send = jnp.full((num_shards, b, k), INVALID, jnp.int32).at[
        jnp.where(ok, sdest, num_shards), jnp.where(ok, slot, b)
    ].set(srows, mode="drop")
    return send


# ---------------------------------------------------------------------------
# SCAN
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("batch", "lt", "gt"))
def scan_batch(src: jax.Array, dst: jax.Array, cursor: jax.Array, total: jax.Array,
               batch: int, lt: Tuple[int, ...], gt: Tuple[int, ...]):
    """Emit one batch of directed-edge matches [batch, 2] starting at cursor.

    ``src``/``dst`` must be padded to a multiple of ``batch`` (engine does
    this) so the dynamic slice never clamps; ``total`` is the true edge count.
    """
    s = lax.dynamic_slice(src, (cursor,), (batch,))
    d = lax.dynamic_slice(dst, (cursor,), (batch,))
    valid = (cursor + jnp.arange(batch)) < total
    rows = jnp.stack([s, d], axis=1)
    mask = valid
    for p in lt:  # col0 < col(p): only p=1 arises for scans
        mask = mask & (rows[:, 0] < rows[:, p])
    for p in gt:
        mask = mask & (rows[:, 0] > rows[:, p])
    rows = jnp.where(mask[:, None], rows, INVALID)
    out, n = compact(rows, mask, batch)
    return out, n


# ---------------------------------------------------------------------------
# PULL-EXTEND — intersect stage (Eq. 2). The fetch stage lives in cache.py /
# distributed.py; on a single device all adjacency is local.
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("ext", "lt", "gt", "out_cap", "use_kernel"),
)
def extend_batch(
    adj: jax.Array,            # int32[V, D] padded sorted adjacency
    rows: jax.Array,           # int32[B, K]
    n: jax.Array,
    ext: Tuple[int, ...],
    lt: Tuple[int, ...],
    gt: Tuple[int, ...],
    out_cap: int,
    use_kernel: bool = False,
):
    b, k = rows.shape
    v = adj.shape[0]
    valid_row = jnp.arange(b) < n

    def nbr_rows(col):
        vids = rows[:, col]
        safe = jnp.clip(vids, 0, v - 1)
        r = jnp.take(adj, safe, axis=0)
        ok = (vids >= 0) & (vids < v)
        return jnp.where(ok[:, None], r, INVALID)

    cands = nbr_rows(ext[0])  # [B, D]
    mask = (cands != INVALID) & valid_row[:, None]
    if len(ext) > 1:
        if use_kernel:
            from repro.kernels.intersect import ops as ik

            others = jnp.stack([nbr_rows(d) for d in ext[1:]], axis=1)  # [B, E-1, D]
            mask = mask & ik.multiway_membership(cands, others)
        else:
            for d in ext[1:]:
                mask = mask & row_membership(nbr_rows(d), cands)
    # Isomorphism (injectivity) check — Alg. 4 line 19.
    for col in range(k):
        mask = mask & (cands != rows[:, col : col + 1])
    # Symmetry-breaking partial orders.
    for p in lt:
        mask = mask & (cands < jnp.where(valid_row, rows[:, p], -1)[:, None])
    for p in gt:
        mask = mask & (cands > jnp.where(valid_row, rows[:, p], INVALID)[:, None])

    d = cands.shape[1]
    expanded = jnp.concatenate(
        [
            jnp.broadcast_to(rows[:, None, :], (b, d, k)),
            cands[:, :, None],
        ],
        axis=2,
    ).reshape(b * d, k + 1)
    return compact(expanded, mask.reshape(b * d), out_cap)


@functools.partial(jax.jit, static_argnames=("ext", "verify_pos", "out_cap"))
def verify_batch(
    adj: jax.Array,
    rows: jax.Array,
    n: jax.Array,
    ext: Tuple[int, ...],
    verify_pos: int,
    out_cap: int,
):
    """Pulling-hash 'hint' (§5.2): keep rows whose f(root) ∈ ∩ N(f(ext))."""
    b, k = rows.shape
    v = adj.shape[0]
    valid_row = jnp.arange(b) < n
    target = rows[:, verify_pos : verify_pos + 1]  # [B, 1]
    mask = valid_row
    for d in ext:
        vids = rows[:, d]
        safe = jnp.clip(vids, 0, v - 1)
        r = jnp.take(adj, safe, axis=0)
        ok = (vids >= 0) & (vids < v)
        r = jnp.where(ok[:, None], r, INVALID)
        mask = mask & row_membership(r, target)[:, 0]
    return compact(rows, mask, out_cap)


# ---------------------------------------------------------------------------
# Delta epochs (DESIGN.md §Delta-plans). For insert-only batches the old
# adjacency is reconstructed as membership-in-new AND NOT membership-in-delta,
# so no pre-batch snapshot is kept; ``old`` is a static bool per intersected
# position. Candidate gathers always read the *new* padded adjacency — an
# old-epoch position only adds a delta-membership veto, keeping the Eq.-2
# structure (and its cost bound) intact.
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("ext", "old", "lt", "gt", "out_cap"),
)
def delta_extend_batch(
    adj: jax.Array,        # int32[V, D]  post-batch padded sorted adjacency
    delta_adj: jax.Array,  # int32[V, Dd] padded sorted adjacency of new edges
    rows: jax.Array,       # int32[B, K]
    n: jax.Array,
    ext: Tuple[int, ...],
    old: Tuple[bool, ...],  # aligned with ext; True → old-epoch edge
    lt: Tuple[int, ...],
    gt: Tuple[int, ...],
    out_cap: int,
):
    b, k = rows.shape
    v = adj.shape[0]
    valid_row = jnp.arange(b) < n

    def nbr_rows(table, col):
        vids = rows[:, col]
        safe = jnp.clip(vids, 0, v - 1)
        r = jnp.take(table, safe, axis=0)
        ok = (vids >= 0) & (vids < v)
        return jnp.where(ok[:, None], r, INVALID)

    cands = nbr_rows(adj, ext[0])  # [B, D]
    mask = (cands != INVALID) & valid_row[:, None]
    if old[0]:
        mask = mask & ~row_membership(nbr_rows(delta_adj, ext[0]), cands)
    for d, is_old in zip(ext[1:], old[1:]):
        mask = mask & row_membership(nbr_rows(adj, d), cands)
        if is_old:
            mask = mask & ~row_membership(nbr_rows(delta_adj, d), cands)
    for col in range(k):
        mask = mask & (cands != rows[:, col : col + 1])
    for p in lt:
        mask = mask & (cands < jnp.where(valid_row, rows[:, p], -1)[:, None])
    for p in gt:
        mask = mask & (cands > jnp.where(valid_row, rows[:, p], INVALID)[:, None])

    d = cands.shape[1]
    expanded = jnp.concatenate(
        [
            jnp.broadcast_to(rows[:, None, :], (b, d, k)),
            cands[:, :, None],
        ],
        axis=2,
    ).reshape(b * d, k + 1)
    return compact(expanded, mask.reshape(b * d), out_cap)


@functools.partial(
    jax.jit, static_argnames=("ext", "old", "verify_pos", "out_cap")
)
def delta_verify_batch(
    adj: jax.Array,
    delta_adj: jax.Array,
    rows: jax.Array,
    n: jax.Array,
    ext: Tuple[int, ...],
    old: Tuple[bool, ...],
    verify_pos: int,
    out_cap: int,
):
    """Epoch-aware VERIFY: f(root) ∈ ∩ N_ep(f(ext)) with per-position epochs."""
    b, k = rows.shape
    v = adj.shape[0]
    valid_row = jnp.arange(b) < n
    target = rows[:, verify_pos : verify_pos + 1]  # [B, 1]
    mask = valid_row

    def nbr_rows(table, col):
        vids = rows[:, col]
        safe = jnp.clip(vids, 0, v - 1)
        r = jnp.take(table, safe, axis=0)
        ok = (vids >= 0) & (vids < v)
        return jnp.where(ok[:, None], r, INVALID)

    for d, is_old in zip(ext, old):
        mask = mask & row_membership(nbr_rows(adj, d), target)[:, 0]
        if is_old:
            mask = mask & ~row_membership(nbr_rows(delta_adj, d), target)[:, 0]
    return compact(rows, mask, out_cap)


# ---------------------------------------------------------------------------
# Fused hot path (DESIGN.md §Fused-hot-path): the cache-probe / fetch-table
# addressing is computed by the engines as a tiny [B, E] prologue; slab
# movement, Eq.-2 intersection, injectivity and symmetry-order filters run in
# one kernel pass (or its ref twin). Expansion and compaction stay out here —
# they are scatter-shaped and gain nothing from fusion.
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("lt", "gt", "out_cap", "force_kernel")
)
def fused_extend_batch(
    tab0: jax.Array,   # int32[R0, D] probe source (cache slabs / fetched table)
    tab1: jax.Array,   # int32[R1, D] fallback (local padded adjacency)
    idx: jax.Array,    # int32[2, B, E]
    sel: jax.Array,    # int32[B, E]
    ok: jax.Array,     # int32[B, E]
    rows: jax.Array,   # int32[B, K]
    n: jax.Array,
    lt: Tuple[int, ...],
    gt: Tuple[int, ...],
    out_cap: int,
    force_kernel: bool = False,
):
    from repro.kernels.intersect import ops as ik

    b, k = rows.shape
    valid_row = jnp.arange(b) < n
    cands, mask = ik.fused_extend(
        tab0, tab1, idx, sel, ok, rows, lt=lt, gt=gt, force_kernel=force_kernel
    )
    mask = mask & valid_row[:, None]
    d = cands.shape[1]
    expanded = jnp.concatenate(
        [
            jnp.broadcast_to(rows[:, None, :], (b, d, k)),
            cands[:, :, None],
        ],
        axis=2,
    ).reshape(b * d, k + 1)
    return compact(expanded, mask.reshape(b * d), out_cap)


@functools.partial(jax.jit, static_argnames=("vpos", "out_cap", "force_kernel"))
def fused_verify_batch(
    tab0: jax.Array,
    tab1: jax.Array,
    idx: jax.Array,
    sel: jax.Array,
    ok: jax.Array,
    rows: jax.Array,
    n: jax.Array,
    vpos: int,
    out_cap: int,
    force_kernel: bool = False,
):
    from repro.kernels.intersect import ops as ik

    b = rows.shape[0]
    valid_row = jnp.arange(b) < n
    keep = ik.fused_verify(
        tab0, tab1, idx, sel, ok, rows, vpos=vpos, force_kernel=force_kernel
    )
    return compact(rows, keep & valid_row, out_cap)


# ---------------------------------------------------------------------------
# PUSH-JOIN — buffered distributed hash join (§4.3). The left side is sorted
# by key once (the paper's external merge sort of the buffered branch); right
# batches then probe it with a vectorised lexicographic binary search and the
# per-key cross products are emitted. This mirrors the paper's "read back the
# data of each join key in a streaming manner" with O(log) probes.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("key_cols",))
def join_prepare(lbuf: jax.Array, ln: jax.Array, key_cols: Tuple[int, ...]):
    """Sort the fully-buffered left side by its join key (invalid rows last)."""
    nl = lbuf.shape[0]
    valid = jnp.arange(nl) < ln
    keys = jnp.where(valid[:, None], lbuf[:, list(key_cols)], INVALID)
    order = lexsort_rows(keys)
    return jnp.take(keys, order, axis=0), jnp.take(lbuf, order, axis=0)


# Lexicographic equal-range search lives with the kernels now: the binary-
# search twin (used here by default) in kernels/intersect/ref.py, the Pallas
# compare-count kernel in kernels/intersect/intersect.py. Re-exported under
# the old names for callers/tests that import them from operators.
from repro.kernels.intersect.ref import _lex_cmp, lex_bounds_ref as _lex_bounds  # noqa: E402


@functools.partial(
    jax.jit,
    static_argnames=(
        "key_right", "right_extra", "cross_neq", "cross_lt", "out_cap",
        "use_kernel", "force_kernel",
    ),
)
def join_probe(
    sorted_keys: jax.Array,   # [CAP, kk] left keys, sorted, INVALID-padded
    sorted_buf: jax.Array,    # [CAP, KL] left rows in the same order
    rrows: jax.Array,         # [B, KR]
    rn: jax.Array,
    key_right: Tuple[int, ...],
    right_extra: Tuple[int, ...],
    cross_neq: Tuple[Tuple[int, int], ...],
    cross_lt: Tuple[Tuple[int, int], ...],
    out_cap: int,
    use_kernel: bool = False,
    force_kernel: bool = False,
):
    b, kr = rrows.shape
    rvalid = jnp.arange(b) < rn
    rkeys = jnp.where(rvalid[:, None], rrows[:, list(key_right)], INVALID - 1)
    if use_kernel:
        from repro.kernels.intersect import ops as ik

        lo, hi = ik.lex_bounds(sorted_keys, rkeys, force_kernel=force_kernel)
    else:
        lo, hi = _lex_bounds(sorted_keys, rkeys)
    cnt = jnp.where(rvalid, hi - lo, 0)
    off = jnp.cumsum(cnt) - cnt
    total = jnp.sum(cnt)

    o = jnp.arange(out_cap, dtype=jnp.int32)
    g = jnp.searchsorted(off + cnt, o, side="right").astype(jnp.int32)
    g = jnp.clip(g, 0, b - 1)
    li = o - jnp.take(off, g)
    lpos = jnp.clip(jnp.take(lo, g) + li, 0, sorted_buf.shape[0] - 1)
    valid = o < total

    lrows_out = jnp.take(sorted_buf, lpos, axis=0)
    rrows_out = jnp.take(rrows, g, axis=0)
    out = (
        jnp.concatenate([lrows_out, rrows_out[:, list(right_extra)]], axis=1)
        if right_extra
        else lrows_out
    )
    for a, bcol in cross_neq:
        valid = valid & (out[:, a] != out[:, bcol])
    for a, bcol in cross_lt:
        valid = valid & (out[:, a] < out[:, bcol])
    out = jnp.where(valid[:, None], out, INVALID)
    out2, nout = compact(out, valid, out_cap)
    return out2, nout, total > out_cap


# ---------------------------------------------------------------------------
# Legacy single-shot group join (kept for the distributed engine's shuffle path
# and property tests).
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("key_left", "key_right", "right_extra", "cross_neq", "cross_lt", "out_cap"),
)
def join_batch(
    lbuf: jax.Array,  # [NL, KL]
    ln: jax.Array,
    rbuf: jax.Array,  # [NR, KR]
    rn: jax.Array,
    key_left: Tuple[int, ...],
    key_right: Tuple[int, ...],
    right_extra: Tuple[int, ...],
    cross_neq: Tuple[Tuple[int, int], ...],
    cross_lt: Tuple[Tuple[int, int], ...],
    out_cap: int,
):
    nl, kl = lbuf.shape
    nr, kr = rbuf.shape
    nn = nl + nr
    kk = len(key_left)

    lvalid = jnp.arange(nl) < ln
    rvalid = jnp.arange(nr) < rn
    lkeys = jnp.where(lvalid[:, None], lbuf[:, list(key_left)], INVALID)
    rkeys = jnp.where(rvalid[:, None], rbuf[:, list(key_right)], INVALID)

    keys = jnp.concatenate([lkeys, rkeys], axis=0)                     # [N, kk]
    side = jnp.concatenate(
        [jnp.zeros(nl, jnp.int32), jnp.ones(nr, jnp.int32)], axis=0
    )
    orig = jnp.concatenate(
        [jnp.arange(nl, dtype=jnp.int32), jnp.arange(nr, dtype=jnp.int32)], axis=0
    )

    order = lexsort_rows(jnp.concatenate([keys, side[:, None]], axis=1))
    sk = jnp.take(keys, order, axis=0)
    ss = jnp.take(side, order)
    so = jnp.take(orig, order)

    newgrp = jnp.concatenate(
        [jnp.ones((1,), bool), jnp.any(sk[1:] != sk[:-1], axis=1)], axis=0
    )
    gid = jnp.cumsum(newgrp.astype(jnp.int32)) - 1                     # [N]
    gstart = jax.ops.segment_min(jnp.arange(nn, dtype=jnp.int32), gid, num_segments=nn)
    lcnt = jax.ops.segment_sum((ss == 0).astype(jnp.int32), gid, num_segments=nn)
    rcnt = jax.ops.segment_sum((ss == 1).astype(jnp.int32), gid, num_segments=nn)
    # Groups keyed by INVALID (out-of-count rows) contribute nothing.
    gkey0 = jnp.full((nn,), INVALID, dtype=jnp.int32).at[gid].min(sk[:, 0])
    pairs = jnp.where(gkey0 == INVALID, 0, lcnt * rcnt)
    out_off = jnp.cumsum(pairs) - pairs                                # exclusive
    total = jnp.sum(pairs)

    o = jnp.arange(out_cap, dtype=jnp.int32)
    g = jnp.searchsorted(out_off + pairs, o, side="right").astype(jnp.int32)
    g = jnp.clip(g, 0, nn - 1)
    local = o - jnp.take(out_off, g)
    rc = jnp.maximum(jnp.take(rcnt, g), 1)
    li = local // rc
    ri = local % rc
    gs = jnp.take(gstart, g)
    lpos = jnp.clip(gs + li, 0, nn - 1)
    rpos = jnp.clip(gs + jnp.take(lcnt, g) + ri, 0, nn - 1)
    lorig = jnp.take(so, lpos)
    rorig = jnp.take(so, rpos)
    valid = o < total

    lrows = jnp.take(lbuf, jnp.clip(lorig, 0, nl - 1), axis=0)
    rrows = jnp.take(rbuf, jnp.clip(rorig, 0, nr - 1), axis=0)
    out = jnp.concatenate([lrows, rrows[:, list(right_extra)]], axis=1) if right_extra else lrows
    for a, bcol in cross_neq:
        valid = valid & (out[:, a] != out[:, bcol])
    for a, bcol in cross_lt:
        valid = valid & (out[:, a] < out[:, bcol])
    out = jnp.where(valid[:, None], out, INVALID)
    out2, nout = compact(out, valid, out_cap)
    overflow = total > out_cap
    return out2, nout, overflow
