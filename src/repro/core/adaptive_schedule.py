"""BFS/DFS-adaptive scheduling applied to training microbatches (paper §5.2).

The paper bounds enumeration memory with fixed-capacity operator queues: run
BFS-style (max parallelism) while the queue has room, fall back to DFS-style
when it fills. For training, the analogue is the gradient-accumulation
microbatch count: one big batch (BFS — best utilisation, max live activation
bytes) vs many microbatches (DFS — minimum memory, some step overhead). We
pick the smallest microbatch count whose estimated live activation bytes fit
the configured queue capacity — the same "as-BFS-as-memory-allows" rule as
Algorithm 5.
"""
from __future__ import annotations

import dataclasses

from repro.models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class MicrobatchDecision:
    num_microbatches: int
    est_activation_bytes: int
    budget_bytes: int
    note: str


def estimate_activation_bytes(cfg: ModelConfig, tokens: int, *, bytes_per_elem: int = 2) -> int:
    """Live activation bytes for one microbatch of ``tokens`` under per-group
    remat: scan saves the block-boundary residual stream per group, plus one
    group's working set (attention q/k/v + mlp hidden)."""
    d = cfg.d_model
    boundaries = cfg.num_groups * tokens * d * bytes_per_elem
    working = tokens * bytes_per_elem * (
        # qkv + attention accumulators (+ mamba/rwkv inner streams ≈ 2·d·expand)
        3 * cfg.num_heads * cfg.hd
        + 2 * max(cfg.d_ff, cfg.moe_d_ff * max(1, cfg.experts_per_token))
        + 4 * d
    ) * cfg.period
    return int(boundaries + working)


def choose_microbatches(
    cfg: ModelConfig,
    global_batch: int,
    seq_len: int,
    *,
    device_count: int = 1,
    budget_bytes: int = 8 << 30,
) -> MicrobatchDecision:
    """Smallest power-of-two microbatch count whose activations fit the queue
    capacity (per device)."""
    n = 1
    while True:
        if global_batch % n:
            n *= 2
            continue
        tokens_per_dev = (global_batch // n) * seq_len // max(1, device_count)
        est = estimate_activation_bytes(cfg, max(1, tokens_per_dev))
        if est <= budget_bytes or n >= global_batch:
            note = "BFS (single batch)" if n == 1 else f"DFS fallback ({n} microbatches)"
            return MicrobatchDecision(n, est, budget_bytes, note)
        n *= 2
