"""Algorithm 2: ExecutionPlanTranslation — plan tree → operator dataflow.

Operators (paper §4.2):
  SCAN        emit matches of a single query edge from the local partition
  PULL-EXTEND extend every partial match by one vertex via the multiway
              intersection of Eq. 2 (two-stage: fetch → intersect)
  VERIFY      the paper's pulling-hash "hint" (§5.2): a PULL-EXTEND that
              matches no new vertex, only verifying f(root) ∈ ∩ N(f(V1))
  PUSH-JOIN   distributed hash join, shuffling both sides by the join key
  SINK        count / materialise final matches

Per §5.2 the translation rewrites (a) star SCANs into an edge SCAN followed
by chained PULL-EXTENDs, and (b) pulling-based hash joins into VERIFY +
chained PULL-EXTENDs — this is what gives the O(|V_q|²·D_G) memory bound.

Schemas: each operator's output rows are tuples of data vertices in a fixed
column order; ``schema[i]`` is the query vertex matched by column ``i``.
Symmetry-breaking conditions (f(a) < f(b)) are installed at the earliest
operator whose output schema contains both endpoints.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.plan import (
    ExecutionPlan,
    PlanNode,
    is_complete_star_join,
    pull_hash_root,
    star_of,
    sub_vertices,
)
from repro.core.query import Edge


@dataclasses.dataclass(frozen=True)
class OpDesc:
    kind: str  # "scan" | "extend" | "verify" | "join" | "sink"
    schema: Tuple[int, ...]
    inputs: Tuple[int, ...] = ()
    # scan
    scan_edge: Optional[Edge] = None
    # extend / verify
    ext: Tuple[int, ...] = ()          # input-schema positions intersected (Eq. 2)
    new_vertex: Optional[int] = None   # extend only
    verify_pos: Optional[int] = None   # verify only: position of the root column
    lt_positions: Tuple[int, ...] = () # candidate <  f[pos]   (symmetry)
    gt_positions: Tuple[int, ...] = () # candidate >  f[pos]
    # join
    key_left: Tuple[int, ...] = ()     # key column positions in left schema
    key_right: Tuple[int, ...] = ()
    right_extra: Tuple[int, ...] = ()  # right-schema positions appended to output
    cross_neq: Tuple[Tuple[int, int], ...] = ()  # (out_a, out_b) must differ
    cross_lt: Tuple[Tuple[int, int], ...] = ()   # out[:, a] < out[:, b]
    # communication mode of this operator: "local" (star-scan extends read the
    # locally-owned root's adjacency), "pull" (fetch-stage GetNbrs) or "push"
    # (BiGJoin-style shuffled wco extends).
    comm: str = "local"
    # Streaming epochs (DESIGN.md §Delta-plans). ``scan_epoch`` is "full"
    # (whole data graph) or "delta" (seed rows from the update batch only).
    # ``ext_epochs`` — aligned with ``ext`` — tags each intersected query edge
    # of an extend/verify: "new" probes the post-batch adjacency, "old" probes
    # post-batch minus the delta (legal for insert batches). Empty means every
    # position is "new", which is what ``translate`` emits.
    scan_epoch: str = "full"
    ext_epochs: Tuple[str, ...] = ()

    def label(self) -> str:
        if self.kind == "scan":
            tag = "Δ" if self.scan_epoch == "delta" else ""
            return f"{tag}SCAN{self.scan_edge}"
        if self.kind == "extend":
            ep = f"|ep={self.ext_epochs}" if self.ext_epochs else ""
            return f"EXT(v{self.new_vertex}|ext={self.ext}{ep})"
        if self.kind == "verify":
            ep = f"|ep={self.ext_epochs}" if self.ext_epochs else ""
            return f"VRF(pos{self.verify_pos}|ext={self.ext}{ep})"
        if self.kind == "join":
            return f"JOIN(key={self.key_left})"
        return "SINK"


@dataclasses.dataclass
class Dataflow:
    """Operator DAG in topological emission order: ``ops[i].inputs`` are always
    indices < i, so any scheduler that walks the list front-to-back sees
    producers before consumers (what the generalised AdaptiveScheduler and
    both engines rely on)."""

    ops: List[OpDesc]
    query_name: str = ""

    @property
    def sink_index(self) -> int:
        return len(self.ops) - 1

    def sink_indices(self) -> Tuple[int, ...]:
        """All sink ops — more than one only for merged multi-tenant flows."""
        return tuple(i for i, op in enumerate(self.ops) if op.kind == "sink")

    def ancestors(self, i: int) -> Tuple[int, ...]:
        """All transitive producers of op ``i`` (excluding ``i``), ascending.

        A PUSH-JOIN's barrier is expressed through this set: the join may only
        probe once every ancestor of its *left* input has drained (DESIGN.md
        §Shuffle-join)."""
        seen: set = set()
        stack = list(self.ops[i].inputs)
        while stack:
            j = stack.pop()
            if j not in seen:
                seen.add(j)
                stack.extend(self.ops[j].inputs)
        return tuple(sorted(seen))

    def num_joins(self) -> int:
        return sum(1 for op in self.ops if op.kind == "join")

    def describe(self) -> str:
        lines = []
        for i, op in enumerate(self.ops):
            ins = ",".join(str(j) for j in op.inputs)
            lines.append(f"[{i}] {op.label():28s} schema={op.schema} <- ({ins})")
        return "\n".join(lines)


class _Translator:
    def __init__(self, plan: ExecutionPlan):
        self.plan = plan
        self.conds = list(plan.symmetry_conditions)
        self.ops: List[OpDesc] = []

    # -- symmetry helpers ----------------------------------------------------

    def _new_vertex_filters(self, schema: Sequence[int], new_v: int):
        """Conditions between the new vertex and already-matched vertices."""
        lt, gt = [], []
        for a, b in self.conds:  # constraint f(a) < f(b)
            if a == new_v and b in schema:
                lt.append(schema.index(b))  # cand < f(b)
            elif b == new_v and a in schema:
                gt.append(schema.index(a))  # cand > f(a)
        return tuple(lt), tuple(gt)

    def _cross_conditions(self, out_schema, left_set, right_set):
        cross = []
        for a, b in self.conds:
            if (a in left_set and b in right_set) or (a in right_set and b in left_set):
                cross.append((out_schema.index(a), out_schema.index(b)))
        return tuple(cross)

    def _emit(self, op: OpDesc) -> int:
        self.ops.append(op)
        return len(self.ops) - 1

    # -- unit translation (star SCAN rewrite, §5.2) ---------------------------

    def _translate_unit(self, node: PlanNode) -> int:
        edges = node.edges
        st = star_of(edges)
        if st is not None:
            root, leaves = st
            leaves = sorted(leaves)
            first = leaves[0]
            schema = (root, first)
            lt, gt = [], []
            for a, b in self.conds:
                if (a, b) == (root, first):
                    lt.append(1)  # col0 < col1
                elif (a, b) == (first, root):
                    gt.append(1)
            idx = self._emit(
                OpDesc(
                    kind="scan",
                    schema=schema,
                    scan_edge=(root, first),
                    lt_positions=tuple(lt),
                    gt_positions=tuple(gt),
                )
            )
            for leaf in leaves[1:]:
                schema_list = list(self.ops[idx].schema)
                flt, fgt = self._new_vertex_filters(schema_list, leaf)
                idx = self._emit(
                    OpDesc(
                        kind="extend",
                        schema=tuple(schema_list + [leaf]),
                        inputs=(idx,),
                        ext=(0,),  # star: all edges from the root (position 0)
                        new_vertex=leaf,
                        lt_positions=flt,
                        gt_positions=fgt,
                    )
                )
            return idx
        # Clique unit (SEED space): edge scan + wco extends over all previous.
        verts = sorted(sub_vertices(edges))
        a, b = verts[0], verts[1]
        schema = (a, b)
        lt, gt = [], []
        for ca, cb in self.conds:
            if (ca, cb) == (a, b):
                lt.append(1)
            elif (ca, cb) == (b, a):
                gt.append(1)
        idx = self._emit(OpDesc(kind="scan", schema=schema, scan_edge=(a, b),
                                lt_positions=tuple(lt), gt_positions=tuple(gt)))
        for v in verts[2:]:
            schema_list = list(self.ops[idx].schema)
            ext = tuple(
                schema_list.index(u)
                for u in schema_list
                if (min(u, v), max(u, v)) in edges
            )
            flt, fgt = self._new_vertex_filters(schema_list, v)
            idx = self._emit(
                OpDesc(
                    kind="extend",
                    schema=tuple(schema_list + [v]),
                    inputs=(idx,),
                    ext=ext,
                    new_vertex=v,
                    lt_positions=flt,
                    gt_positions=fgt,
                    comm="pull",
                )
            )
        return idx

    # -- join translation ------------------------------------------------------

    def _translate(self, node: PlanNode) -> int:
        if node.is_leaf:
            return self._translate_unit(node)

        if node.algo == "wco" and node.comm == "pull":
            # Complete star join → PULL-EXTEND (Alg. 2 lines 12-18).
            csj = is_complete_star_join(node.left.edges, node.right.edges)
            right_node, left_node = node.right, node.left
            if csj is None:  # orientation was flipped by the optimiser
                csj = is_complete_star_join(node.right.edges, node.left.edges)
                right_node, left_node = node.left, node.right
            root, leaves = csj
            in_idx = self._translate(left_node)
            schema_list = list(self.ops[in_idx].schema)
            ext = tuple(schema_list.index(l) for l in sorted(leaves))
            lt, gt = self._new_vertex_filters(schema_list, root)
            return self._emit(
                OpDesc(
                    kind="extend",
                    schema=tuple(schema_list + [root]),
                    inputs=(in_idx,),
                    ext=ext,
                    new_vertex=root,
                    lt_positions=lt,
                    gt_positions=gt,
                    comm=node.comm or "pull",
                )
            )

        if node.algo == "hash" and node.comm == "pull":
            # Pulling hash join → VERIFY + chained PULL-EXTENDs (§5.2).
            ph = pull_hash_root(node.left.edges, node.right.edges)
            right_node, left_node = node.right, node.left
            if ph is None:
                ph = pull_hash_root(node.right.edges, node.left.edges)
                right_node, left_node = node.left, node.right
            root, leaves = ph
            in_idx = self._translate(left_node)
            schema_list = list(self.ops[in_idx].schema)
            v1 = sorted(l for l in leaves if l in schema_list)
            v2 = sorted(l for l in leaves if l not in schema_list)
            idx = in_idx
            if v1:
                idx = self._emit(
                    OpDesc(
                        kind="verify",
                        schema=tuple(schema_list),
                        inputs=(idx,),
                        ext=tuple(schema_list.index(l) for l in v1),
                        verify_pos=schema_list.index(root),
                        comm="pull",
                    )
                )
            for v in v2:
                schema_list = list(self.ops[idx].schema)
                lt, gt = self._new_vertex_filters(schema_list, v)
                idx = self._emit(
                    OpDesc(
                        kind="extend",
                        schema=tuple(schema_list + [v]),
                        inputs=(idx,),
                        ext=(schema_list.index(root),),
                        new_vertex=v,
                        lt_positions=lt,
                        gt_positions=gt,
                        comm="pull",
                    )
                )
            return idx

        # Pushing hash join → PUSH-JOIN.
        li = self._translate(node.left)
        ri = self._translate(node.right)
        ls = list(self.ops[li].schema)
        rs = list(self.ops[ri].schema)
        key = sorted(set(ls) & set(rs))
        assert key, "join key must be non-empty"
        right_extra_verts = [v for v in rs if v not in ls]
        out_schema = tuple(ls + right_extra_verts)
        left_only = set(ls) - set(key)
        right_only = set(right_extra_verts)
        cross_neq = tuple(
            (out_schema.index(a), out_schema.index(b))
            for a in sorted(left_only)
            for b in sorted(right_only)
        )
        cross_lt = self._cross_conditions(out_schema, set(ls), right_only)
        return self._emit(
            OpDesc(
                kind="join",
                comm="push",
                schema=out_schema,
                inputs=(li, ri),
                key_left=tuple(ls.index(k) for k in key),
                key_right=tuple(rs.index(k) for k in key),
                right_extra=tuple(rs.index(v) for v in right_extra_verts),
                cross_neq=cross_neq,
                cross_lt=cross_lt,
            )
        )

    def run(self) -> Dataflow:
        last = self._translate(self.plan.root)
        final_schema = self.ops[last].schema
        assert set(final_schema) == set(range(self.plan.query.num_vertices)), (
            f"plan does not cover query: {final_schema}"
        )
        self._emit(OpDesc(kind="sink", schema=final_schema, inputs=(last,)))
        return Dataflow(ops=self.ops, query_name=self.plan.query.name)


def translate(plan: ExecutionPlan) -> Dataflow:
    """Paper Algorithm 2."""
    return _Translator(plan).run()


# ---------------------------------------------------------------------------
# Delta-join decomposition for streaming updates (DESIGN.md §Delta-plans)
# ---------------------------------------------------------------------------

def delta_edge_order(query) -> List[Edge]:
    """Canonical total order over the query's edges.

    The exactly-once guarantee of :func:`delta_flows` hinges on every caller
    agreeing on this order: flow ``i`` emits a match iff ``i`` is the *minimum*
    index whose query edge lands on a delta data edge."""
    return sorted(query.edges)


def delta_flows(plan: ExecutionPlan, batch=None) -> List[Dataflow]:
    """Delta-join decomposition: one dataflow per query edge.

    For a k-edge query with canonical edge order ``e_0 < … < e_{k-1}``, flow
    ``i`` scans matches of ``e_i`` from the *delta* (new edges only), then
    extends to the remaining query vertices; each query edge ``e_j`` checked
    along the way probes the **old** adjacency when ``j < i`` and the **new**
    adjacency when ``j > i``. A new match whose query edges land on delta
    data edges at index set ``S ≠ ∅`` is produced exactly by flow ``min(S)``
    — no duplicates, no misses — and an unchanged match (``S = ∅``) by none.

    The flows depend only on the query (not the batch contents), so standing
    queries translate once and re-execute per batch; ``batch`` is accepted
    for the natural call shape and only used to short-circuit empty batches.
    Every extend intersects over *all* already-matched neighbours of the new
    vertex (Eq. 2), so each query edge is enforced exactly once — at the op
    where its second endpoint enters the schema — and no trailing VERIFY is
    needed. Symmetry-breaking conditions are installed exactly as in
    :func:`translate`, so per-automorphism-class dedup carries over."""
    if batch is not None and getattr(batch, "num_edges", None) == 0:
        return []
    query = plan.query
    order = delta_edge_order(query)
    index_of = {e: i for i, e in enumerate(order)}
    qadj = query.adjacency()
    conds = list(plan.symmetry_conditions)
    flows: List[Dataflow] = []

    for i, (a, b) in enumerate(order):
        ops: List[OpDesc] = []
        schema = [a, b]
        lt, gt = [], []
        for ca, cb in conds:
            if (ca, cb) == (a, b):
                lt.append(1)
            elif (ca, cb) == (b, a):
                gt.append(1)
        ops.append(
            OpDesc(
                kind="scan",
                schema=(a, b),
                scan_edge=(a, b),
                scan_epoch="delta",
                lt_positions=tuple(lt),
                gt_positions=tuple(gt),
            )
        )
        while len(schema) < query.num_vertices:
            # Greedy: next vertex with the most matched neighbours (densest
            # Eq.-2 intersection first), smallest id on ties — deterministic.
            candidates = [
                v for v in range(query.num_vertices)
                if v not in schema and any(u in schema for u in qadj[v])
            ]
            v = max(candidates, key=lambda c: (len(qadj[c] & set(schema)), -c))
            ext, epochs = [], []
            for p, u in enumerate(schema):
                if u in qadj[v]:
                    ext.append(p)
                    j = index_of[(min(u, v), max(u, v))]
                    epochs.append("old" if j < i else "new")
            flt, fgt = [], []
            for ca, cb in conds:
                if ca == v and cb in schema:
                    flt.append(schema.index(cb))
                elif cb == v and ca in schema:
                    fgt.append(schema.index(ca))
            ops.append(
                OpDesc(
                    kind="extend",
                    schema=tuple(schema + [v]),
                    inputs=(len(ops) - 1,),
                    ext=tuple(ext),
                    ext_epochs=tuple(epochs),
                    new_vertex=v,
                    lt_positions=tuple(flt),
                    gt_positions=tuple(fgt),
                    comm="pull",
                )
            )
            schema.append(v)
        ops.append(OpDesc(kind="sink", schema=tuple(schema), inputs=(len(ops) - 1,)))
        flows.append(Dataflow(ops=ops, query_name=f"Δ{i}:{query.name}"))
    return flows


def merge_flows(flows: Sequence[Dataflow]) -> Tuple[Dataflow, Tuple[int, ...]]:
    """Concatenate independent dataflows into one multi-sink DAG.

    Returns ``(merged, tenant_of_op)`` where ``tenant_of_op[i]`` is the index
    of the source flow op ``i`` came from. Concatenating per-flow topological
    orders yields a valid topological order of the union (there are no cross-
    flow edges), so one AdaptiveScheduler pass over the merged op list
    interleaves runnable ops across tenants — this is how N concurrent
    queries share a single engine's scheduler tick (serve/graph_service.py,
    distributed.run_concurrent). Per-tenant results stay separable because
    each flow keeps its own sink (``merged.sink_indices()``, in input order)."""
    ops: List[OpDesc] = []
    tenant_of_op: List[int] = []
    for t, flow in enumerate(flows):
        off = len(ops)
        for op in flow.ops:
            ops.append(
                dataclasses.replace(op, inputs=tuple(j + off for j in op.inputs))
            )
            tenant_of_op.append(t)
    name = "+".join(f.query_name or f"flow{t}" for t, f in enumerate(flows))
    return Dataflow(ops=ops, query_name=name), tuple(tenant_of_op)
