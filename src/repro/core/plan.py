"""Logical + physical execution plans (paper Section 3).

A sub-query is a frozenset of canonical edges of the query graph. A plan is a
binary join tree whose leaves are *join units* (stars; optionally cliques for
the SEED plan space) and whose internal nodes are two-way joins
``(q', q'_l, q'_r)``. Physical settings per join follow Eq. 3:

    (wco,  pull) if the join is a *complete star join*        (Def. 3.1)
    (hash, pull) if q'_r is a star (root; L) with root ∈ V_l  (Property 3.1 C1)
    (hash, push) otherwise

Plan *spaces* reproduce Table 2: each prior system is the same optimiser run
under that system's constraints (join unit / order / algorithm / comm mode).
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, List, Optional, Sequence, Tuple

from repro.core.query import Edge, QueryGraph, _canon

SubQuery = FrozenSet[Edge]


# ---------------------------------------------------------------------------
# Sub-query helpers
# ---------------------------------------------------------------------------

def sub_vertices(edges: SubQuery) -> FrozenSet[int]:
    return frozenset(v for e in edges for v in e)


def is_connected(edges: SubQuery) -> bool:
    if not edges:
        return False
    verts = sub_vertices(edges)
    seen = {next(iter(verts))}
    frontier = list(seen)
    adj = {v: set() for v in verts}
    for a, b in edges:
        adj[a].add(b)
        adj[b].add(a)
    while frontier:
        v = frontier.pop()
        for u in adj[v]:
            if u not in seen:
                seen.add(u)
                frontier.append(u)
    return seen == verts


def star_of(edges: SubQuery) -> Optional[Tuple[int, FrozenSet[int]]]:
    """Return (root, leaves) if ``edges`` forms a star, else None.

    A single edge is a 1-star; we root it at its smaller endpoint.
    """
    if not edges:
        return None
    if len(edges) == 1:
        a, b = next(iter(edges))
        return a, frozenset([b])
    common = None
    for a, b in edges:
        cur = {a, b}
        common = cur if common is None else (common & cur)
    if not common:
        return None
    root = min(common)
    leaves = frozenset(v for e in edges for v in e if v != root)
    if len(leaves) != len(edges):
        return None
    return root, leaves


def is_clique_sub(edges: SubQuery) -> bool:
    verts = sub_vertices(edges)
    n = len(verts)
    return n >= 3 and len(edges) == n * (n - 1) // 2


def is_complete_star_join(left: SubQuery, right: SubQuery) -> Optional[Tuple[int, FrozenSet[int]]]:
    """Definition 3.1: the right side is a star whose *root* is a new vertex
    and whose leaves are all already matched on the left (BiGJoin's
    vertex-extension as a join). Returns (root, leaves) or None."""
    st = star_of(right)
    if st is None:
        return None
    root, leaves = st
    lv = sub_vertices(left)
    if root not in lv and leaves <= lv:
        return root, leaves
    # A single edge is symmetric: try the other rooting.
    if len(right) == 1:
        (a, b) = next(iter(right))
        if b not in lv and a in lv:
            return b, frozenset([a])
    return None


def pull_hash_root(left: SubQuery, right: SubQuery) -> Optional[Tuple[int, FrozenSet[int]]]:
    """Property 3.1 C1: right is a star whose root is already matched on the
    left. Returns (root, leaves) or None."""
    st = star_of(right)
    if st is None:
        return None
    root, leaves = st
    lv = sub_vertices(left)
    if root in lv:
        return root, leaves
    if len(right) == 1:
        (a, b) = next(iter(right))
        if b in lv:
            return b, frozenset([a])
    return None


# ---------------------------------------------------------------------------
# Plan tree
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanNode:
    """A node of the join tree. Leaves have no children and a join-unit edge set."""

    edges: SubQuery
    left: Optional["PlanNode"] = None
    right: Optional["PlanNode"] = None
    algo: Optional[str] = None  # "hash" | "wco"     (joins only)
    comm: Optional[str] = None  # "push" | "pull"    (joins only)

    @property
    def is_leaf(self) -> bool:
        return self.left is None

    def vertices(self) -> FrozenSet[int]:
        return sub_vertices(self.edges)

    def depth(self) -> int:
        if self.is_leaf:
            return 1
        return 1 + max(self.left.depth(), self.right.depth())

    def describe(self, indent: int = 0) -> str:
        pad = "  " * indent
        vs = sorted(self.vertices())
        if self.is_leaf:
            st = star_of(self.edges)
            kind = f"star root={st[0]}" if st else "unit"
            return f"{pad}SCAN {vs} ({kind})"
        head = f"{pad}JOIN {vs} [{self.algo}/{self.comm}]"
        return "\n".join([head, self.left.describe(indent + 1), self.right.describe(indent + 1)])


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    query: QueryGraph
    root: PlanNode
    symmetry_conditions: Tuple[Edge, ...]
    est_cost: float = 0.0

    def describe(self) -> str:
        conds = ", ".join(f"v{a}<v{b}" for a, b in self.symmetry_conditions)
        return (
            f"plan for {self.query.name} (est cost {self.est_cost:.3g})\n"
            f"symmetry: [{conds}]\n" + self.root.describe()
        )


# ---------------------------------------------------------------------------
# Plan spaces — Table 2
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanSpace:
    """Constraints under which the optimiser searches (Table 2 presets)."""

    name: str
    units: Tuple[str, ...] = ("star",)          # "star" and/or "clique"
    order: str = "bushy"                         # "bushy" | "leftdeep"
    algos: Tuple[str, ...] = ("hash", "wco")
    comms: Tuple[str, ...] = ("push", "pull")
    complete_star_only: bool = False             # BiGJoin/BENU: rhs must extend one vertex
    unit_max_edges: Optional[int] = None         # BiGJoin/BENU scan single edges only


PLAN_SPACES = {
    # Table 2 rows.
    "starjoin": PlanSpace("starjoin", units=("star",), order="leftdeep", algos=("hash",), comms=("push",)),
    "seed": PlanSpace("seed", units=("star", "clique"), order="bushy", algos=("hash",), comms=("push",)),
    "bigjoin": PlanSpace("bigjoin", units=("star",), order="leftdeep", algos=("wco",), comms=("push",), complete_star_only=True, unit_max_edges=1),
    "benu": PlanSpace("benu", units=("star",), order="leftdeep", algos=("wco",), comms=("pull",), complete_star_only=True, unit_max_edges=1),
    "rads": PlanSpace("rads", units=("star",), order="leftdeep", algos=("hash",), comms=("pull", "push")),
    # HUGE: the full hybrid space.
    "huge": PlanSpace("huge", units=("star",), order="bushy", algos=("hash", "wco"), comms=("push", "pull")),
    # Sequential-context hybrid planners (Exp-9): computation-only cost.
    "emptyheaded": PlanSpace("emptyheaded", units=("star",), order="bushy", algos=("hash", "wco"), comms=("push",)),
    "graphflow": PlanSpace("graphflow", units=("star",), order="bushy", algos=("hash", "wco"), comms=("push",)),
}


def assign_physical(left: SubQuery, right: SubQuery, space: PlanSpace) -> Tuple[str, str]:
    """Eq. 3, restricted to the plan space's allowed algorithms/comm modes."""
    csj = is_complete_star_join(left, right)
    if csj is not None and "wco" in space.algos:
        comm = "pull" if "pull" in space.comms else "push"
        return "wco", comm
    ph = pull_hash_root(left, right)
    if ph is not None and "pull" in space.comms and "hash" in space.algos:
        return "hash", "pull"
    if "hash" in space.algos and "push" in space.comms:
        return "hash", "push"
    if "hash" in space.algos:  # pull-only hash system (RADS always may push? keep pull)
        return "hash", "pull" if "pull" in space.comms else "push"
    # wco-only system forced to push (BiGJoin).
    return "wco", "push" if "push" in space.comms else "pull"
