"""Distributed HUGE engine: shard_map SPMD execution of PULL-EXTEND chains.

This is the real-collective counterpart of engine.py: the graph is hash-
partitioned over the mesh axis ``shards`` (paper §2), partial matches live on
their producing shard, and each PULL-EXTEND executes the paper's two-stage
strategy with actual communication:

  fetch stage     dedup the batch's remote vertices (merged-RPC aggregation),
                  route requests to their owners with an ``all_to_all``,
                  gather CSR rows, return them with a second ``all_to_all``
                  — the GetNbrs RPC as a dense collective;
  intersect stage read-only: Eq. 2 membership over local partition + the
                  fetched table (zero-copy in the paper's sense: pure gather);
  stealing        each batch's results are re-spread evenly with one more
                  ``all_to_all`` (proactive inter-machine work stealing, §5.3
                  — see DESIGN.md on why SPMD makes stealing deterministic).

Scope: extend/verify-chain dataflows (wco plans — the paper's core path).
Plans with PUSH-JOIN barriers run on the single-process engine (the
distributed shuffle join is the same hash-a2a machinery; DESIGN.md).

Memory bound: every queue is a preallocated [P, CAP, K] device array — the
paper's Theorem 5.4 bound is structural.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import operators as ops_mod
from repro.core.dataflow import Dataflow, OpDesc, translate
from repro.core.optimizer import optimal_plan
from repro.core.cost import GraphStats
from repro.core.query import QueryGraph
from repro.graph.partition import partition_graph
from repro.graph.storage import Graph, INVALID


@dataclasses.dataclass
class DistConfig:
    batch_size: int = 256
    queue_capacity: int = 1 << 16
    axis: str = "shards"
    rebalance: bool = True           # inter-machine work stealing


def wco_chain(flow: Dataflow) -> Optional[List[OpDesc]]:
    """The op chain if the dataflow is a pure scan→(extend|verify)*→sink line."""
    ops = flow.ops
    if ops[0].kind != "scan" or ops[-1].kind != "sink":
        return None
    for op in ops[1:-1]:
        if op.kind not in ("extend", "verify"):
            return None
    return list(ops)


class DistributedEngine:
    def __init__(self, graph: Graph, mesh: Mesh, cfg: DistConfig | None = None):
        self.cfg = cfg or DistConfig()
        self.mesh = mesh
        self.axis = self.cfg.axis
        self.p = mesh.shape[self.axis]
        self.pg = partition_graph(graph, self.p)
        self.graph = graph
        self.v = graph.num_vertices
        self.d_pad = self.pg.d_pad
        self.sh = lambda ndim: NamedSharding(mesh, P(self.axis, *([None] * (ndim - 1))))
        self.adj = jax.device_put(self.pg.adj, self.sh(3))
        # per-shard directed edge lists, padded to the max shard size
        offsets = np.asarray(graph.offsets)
        deg_np = np.diff(offsets)
        src_all = np.repeat(np.arange(self.v, dtype=np.int32), deg_np)
        dst_all = np.asarray(graph.nbrs, dtype=np.int32)
        owners = src_all % self.p
        b = self.cfg.batch_size
        max_e = max(int((owners == p).sum()) for p in range(self.p))
        max_e = max(b, ((max_e + b - 1) // b) * b)
        src = np.zeros((self.p, max_e), np.int32)
        dst = np.full((self.p, max_e), INVALID, np.int32)
        totals = np.zeros((self.p,), np.int32)
        for p in range(self.p):
            sel = owners == p
            n = int(sel.sum())
            src[p, :n] = src_all[sel]
            dst[p, :n] = dst_all[sel]
            totals[p] = n
        self.src = jax.device_put(jnp.asarray(src), self.sh(2))
        self.dst = jax.device_put(jnp.asarray(dst), self.sh(2))
        self.scan_totals = jax.device_put(jnp.asarray(totals), self.sh(1))
        self.stats: Dict[str, float] = {}

    # ------------------------------------------------------------------
    # shard-local pieces (inside shard_map; no leading P dim)
    # ------------------------------------------------------------------

    def _fetch(self, adj, rows, valid_rows, ext):
        """Fetch stage: dedup needed vids, owner-routed exchange, return a
        sorted lookup table (vids, adjacency rows)."""
        p, axis = self.p, self.axis
        vids = rows[:, list(ext)].reshape(-1)
        ok = (
            (vids != INVALID)
            & (vids >= 0)
            & jnp.repeat(valid_rows[:, None], len(ext), 1).reshape(-1)
        )
        r_cap = vids.shape[0]
        owner = jnp.where(ok, vids % p, p)
        key = jnp.where(ok, owner * self.v + vids, p * self.v)
        skey = jnp.sort(key)
        uniq = (skey < p * self.v) & jnp.concatenate(
            [jnp.ones((1,), bool), skey[1:] != skey[:-1]]
        )
        o_s = jnp.where(uniq, skey // self.v, p)
        v_s = jnp.where(uniq, skey % self.v, INVALID)
        cnt = jax.ops.segment_sum(uniq.astype(jnp.int32), o_s, num_segments=p + 1)[:p]
        offs = jnp.cumsum(cnt) - cnt
        rank = jnp.cumsum(uniq.astype(jnp.int32)) - 1
        slot = rank - jnp.take(
            jnp.concatenate([offs, jnp.zeros(1, jnp.int32)]), jnp.minimum(o_s, p)
        )
        reqs = jnp.full((p, r_cap), INVALID, jnp.int32).at[
            jnp.where(uniq, o_s, p), jnp.where(uniq, slot, r_cap)
        ].set(v_s, mode="drop")
        got = jax.lax.all_to_all(reqs, axis, split_axis=0, concat_axis=0, tiled=True)
        lid = jnp.clip(jnp.where(got != INVALID, got // p, 0), 0, adj.shape[0] - 1)
        served = jnp.take(adj, lid.reshape(-1), axis=0).reshape(p, r_cap, -1)
        served = jnp.where((got != INVALID)[:, :, None], served, INVALID)
        back = jax.lax.all_to_all(served, axis, split_axis=0, concat_axis=0, tiled=True)
        back_vids = reqs.reshape(-1)
        order = jnp.argsort(back_vids)
        return jnp.take(back_vids, order), jnp.take(
            back.reshape(-1, adj.shape[-1]), order, axis=0
        )

    def _lookup(self, table_vids, table_rows, adj, vids):
        p = self.p
        me = jax.lax.axis_index(self.axis)
        ok = (vids != INVALID) & (vids >= 0)
        local = ok & ((vids % p) == me)
        lrows = jnp.take(
            adj, jnp.clip(jnp.where(ok, vids // p, 0), 0, adj.shape[0] - 1), axis=0
        )
        idx = jnp.clip(jnp.searchsorted(table_vids, vids), 0, table_vids.shape[0] - 1)
        hit = jnp.take(table_vids, idx) == vids
        rrows = jnp.take(table_rows, idx, axis=0)
        rows = jnp.where(local[:, None], lrows, jnp.where(hit[:, None], rrows, INVALID))
        return jnp.where(ok[:, None], rows, INVALID)

    # ------------------------------------------------------------------
    # jitted shard_map step programs
    # ------------------------------------------------------------------

    def _shardmap(self, f, n_in, n_out):
        ax = self.axis
        return jax.jit(
            shard_map(
                f,
                mesh=self.mesh,
                in_specs=tuple(P(ax) for _ in range(n_in)),
                out_specs=tuple(P(ax) for _ in range(n_out)) if n_out > 1 else P(ax),
                check_rep=False,
            )
        )

    def _build_scan_step(self, op: OpDesc):
        b = self.cfg.batch_size
        lt, gt = op.lt_positions, op.gt_positions

        def f(src, dst, total, cursor, qbuf, qn):
            rows, n = ops_mod.scan_batch(src[0], dst[0], cursor[0], total[0], b, lt, gt)
            buf, n2 = ops_mod.queue_append(qbuf[0], qn[0], rows, n)
            return buf[None], n2[None]

        return self._shardmap(f, 6, 2)

    def _build_extend_step(self, op: OpDesc, is_verify: bool):
        b = self.cfg.batch_size
        ext, lt, gt = op.ext, op.lt_positions, op.gt_positions
        vpos = op.verify_pos
        rebalance = self.cfg.rebalance
        p = self.p

        def f(adj3, in_buf, in_n, out_buf, out_n):
            adj = adj3[0]
            rows, take, rem = ops_mod.queue_pop(in_buf[0], in_n[0], b)
            valid = jnp.arange(b) < take
            tv, tr = self._fetch(adj, rows, valid, ext)
            k = rows.shape[1]
            if is_verify:
                target = rows[:, vpos : vpos + 1]
                mask = valid
                for d in ext:
                    other = self._lookup(tv, tr, adj, rows[:, d])
                    mask = mask & ops_mod.row_membership(other, target)[:, 0]
                new_rows, m = ops_mod.compact(rows, mask, b)
                out_w = b
            else:
                cands = self._lookup(tv, tr, adj, rows[:, ext[0]])
                mask = (cands != INVALID) & valid[:, None]
                for d in ext[1:]:
                    other = self._lookup(tv, tr, adj, rows[:, d])
                    mask = mask & ops_mod.row_membership(other, cands)
                for col in range(k):
                    mask = mask & (cands != rows[:, col : col + 1])
                for pp in lt:
                    mask = mask & (cands < jnp.where(valid, rows[:, pp], -1)[:, None])
                for pp in gt:
                    mask = mask & (cands > jnp.where(valid, rows[:, pp], INVALID)[:, None])
                d_pad = cands.shape[1]
                expanded = jnp.concatenate(
                    [jnp.broadcast_to(rows[:, None, :], (b, d_pad, k)), cands[:, :, None]],
                    axis=2,
                ).reshape(b * d_pad, k + 1)
                new_rows, m = ops_mod.compact(expanded, mask.reshape(-1), b * d_pad)
                out_w = b * d_pad
                k = k + 1
            if rebalance and out_w >= p:
                share = out_w // p
                chunks = new_rows[: share * p].reshape(p, share, k)
                cvalid = (jnp.arange(share * p) < m).reshape(p, share)
                got = jax.lax.all_to_all(chunks, self.axis, split_axis=0, concat_axis=0, tiled=True)
                gvalid = jax.lax.all_to_all(cvalid, self.axis, split_axis=0, concat_axis=0, tiled=True)
                new_rows, m = ops_mod.compact(got.reshape(-1, k), gvalid.reshape(-1), out_w)
            buf, n2 = ops_mod.queue_append(out_buf[0], out_n[0], new_rows, m)
            return rem[None], buf[None], n2[None]

        return self._shardmap(f, 5, 3)

    # ------------------------------------------------------------------

    def run(self, query: QueryGraph, space: str = "huge") -> Tuple[int, Dict]:
        plan = optimal_plan(query, GraphStats.from_graph(self.graph), self.p, space)
        flow = translate(plan)
        chain = wco_chain(flow)
        if chain is None:
            raise ValueError(
                "distributed engine runs extend/verify-chain plans; this plan "
                "has a PUSH-JOIN barrier — use the single-process engine"
            )
        b = self.cfg.batch_size
        cap = self.cfg.queue_capacity
        bufs, ns = {}, {}
        for i, op in enumerate(chain[:-1]):
            width = len(op.schema)
            slack = b if op.kind in ("scan", "verify") else b * self.d_pad
            bufs[i] = jax.device_put(
                jnp.full((self.p, cap + slack, width), INVALID, jnp.int32), self.sh(3)
            )
            ns[i] = jax.device_put(jnp.zeros((self.p,), jnp.int32), self.sh(1))
        cursor = jax.device_put(jnp.zeros((self.p,), jnp.int32), self.sh(1))

        scan_step = self._build_scan_step(chain[0])
        steps = {
            i: self._build_extend_step(op, op.kind == "verify")
            for i, op in enumerate(chain)
            if op.kind in ("extend", "verify")
        }
        total_count = 0
        rounds = 0
        scan_rounds = self.src.shape[1] // b
        scans_done = 0
        while True:
            progressed = False
            if scans_done < scan_rounds and cap - int(jnp.max(ns[0])) >= b:
                bufs[0], ns[0] = scan_step(
                    self.src, self.dst, self.scan_totals, cursor, bufs[0], ns[0]
                )
                cursor = cursor + b
                scans_done += 1
                rounds += 1
                progressed = True
            for i, op in enumerate(chain):
                if i not in steps:
                    continue
                in_i = i - 1
                if int(jnp.max(ns[in_i])) <= 0:
                    continue
                is_last = i == len(chain) - 2
                slack = b if op.kind == "verify" else b * self.d_pad
                if not is_last and cap - int(jnp.max(ns[i])) < slack:
                    continue
                ns[in_i], bufs[i], ns[i] = steps[i](
                    self.adj, bufs[in_i], ns[in_i], bufs[i], ns[i]
                )
                rounds += 1
                progressed = True
                if is_last:
                    total_count += int(jnp.sum(ns[i]))
                    ns[i] = jax.device_put(jnp.zeros((self.p,), jnp.int32), self.sh(1))
            if not progressed:
                break
        self.stats = {"rounds": rounds, "shards": self.p}
        return total_count, self.stats
