"""Distributed HUGE engine: shard_map SPMD execution of arbitrary plan DAGs.

This is the real-collective counterpart of engine.py: the graph is hash-
partitioned over the mesh axis ``shards`` (paper §2), partial matches live on
their producing shard, and every operator of the translated dataflow — SCAN,
PULL-EXTEND, VERIFY, PUSH-JOIN, SINK — executes with actual communication:

  fetch stage     dedup the batch's remote vertices (merged-RPC aggregation),
                  route requests to their owners with an ``all_to_all``,
                  gather CSR rows, return them with a second ``all_to_all``
                  — the GetNbrs RPC as a dense collective;
  intersect stage read-only: Eq. 2 membership over local partition + the
                  fetched table (zero-copy in the paper's sense: pure gather);
  shuffle join    PUSH-JOIN hash-partitions *both* inputs by join key with an
                  ``all_to_all`` (the paper's shuffle of R(q'_l), R(q'_r));
                  matching keys co-locate, so the probe itself is local —
                  DESIGN.md §Shuffle-join;
  stealing        each extend batch's results are re-spread evenly with one
                  more ``all_to_all`` (proactive inter-machine work stealing,
                  §5.3 — see DESIGN.md §SPMD-work-stealing on why SPMD makes
                  stealing deterministic).

Scope: any optimiser plan — scan → {extend, verify, join} DAGs, driven by the
generalised BFS/DFS-adaptive scheduler (scheduler.py) over the dataflow's
topological order. PUSH-JOIN is a barrier operator: it shuffle-buffers either
input whenever rows are available, but only probes once every ancestor of its
buffered (left) branch has drained.

Memory bound: every queue — operator output queues *and* join side buffers —
is a preallocated [P, CAP, K] device array, so the paper's Theorem 5.4 bound
stays structural (a compile-time constant, not a runtime promise).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.analysis.flowcheck import verify_flow
from repro.core import operators as ops_mod
from repro.core.faults import (
    EnumerationFault,
    FaultPlan,
    QueuePressure,
    ShardLoss,
)
from repro.core.dataflow import Dataflow, OpDesc, merge_flows, translate
from repro.core.optimizer import optimal_plan
from repro.core.cost import GraphStats
from repro.core.plan import ExecutionPlan
from repro.core.query import QueryGraph
from repro.core.scheduler import AdaptiveScheduler
from repro.graph.partition import partition_graph
from repro.graph.storage import Graph, INVALID


_log = logging.getLogger("repro.distributed")


@dataclasses.dataclass
class DistConfig:
    batch_size: int = 256
    queue_capacity: int = 1 << 16
    join_buffer_capacity: int = 1 << 17  # rows per join side buffer per shard
    join_out_capacity: int = 1 << 17     # worst-case rows per probe step
    axis: str = "shards"
    rebalance: bool = True               # inter-machine work stealing
    fused: bool = False                  # fused extend/verify + probe kernels
    force_kernel: bool = False           # interpret-mode kernels on CPU (CI)
    faults: Optional[FaultPlan] = None   # deterministic fault injection
    recover: bool = True                 # restart-based recovery: SPMD
    #   execution is deterministic, so a recoverable fault rebuilds the
    #   runtimes and re-runs the flow (halved batch on QueuePressure)
    max_retries: int = 3                 # recovery attempts per _execute
    min_batch_size: int = 32             # degradation floor for batch halving


class _DQueue:
    """A distributed device queue: ``buf[P, cap+slack, K]`` + counts ``n[P]``.

    ``capacity`` is the logical fill level the scheduler gates on; ``slack``
    absorbs one worst-case batch beyond it (Lemma 5.2 overflow allowance).
    The host caches ``max(n)`` so scheduling decisions don't re-sync."""

    def __init__(self, eng: "DistributedEngine", width: int, slack: int,
                 capacity: Optional[int] = None):
        cap = eng.cfg.queue_capacity if capacity is None else capacity
        self.capacity = cap + slack  # physical rows, engine.DeviceQueue-style
        self.width = width
        self.buf = jax.device_put(
            jnp.full((eng.p, cap + slack, width), INVALID, jnp.int32), eng.sh(3)
        )
        self.n = jax.device_put(jnp.zeros((eng.p,), jnp.int32), eng.sh(1))
        self._eng = eng
        self._max = 0
        self._dirty = False

    def set(self, buf: jax.Array, n: jax.Array) -> None:
        self.buf, self.n = buf, n
        self._dirty = True

    def set_n(self, n: jax.Array) -> None:
        self.n = n
        self._dirty = True

    @property
    def max_n(self) -> int:
        if self._dirty:
            self._max = int(jnp.max(self.n))
            self._dirty = False
        return self._max

    def total(self) -> int:
        return int(jnp.sum(self.n))

    def free(self) -> int:
        """Physical free rows; gate ``free() >= worst_case_batch`` before
        running a producer (the Lemma 5.2 slack invariant)."""
        return self.capacity - self.max_n

    def drain(self) -> None:
        self.n = jax.device_put(
            jnp.zeros((self._eng.p,), jnp.int32), self._eng.sh(1)
        )
        self._max = 0
        self._dirty = False


# ---------------------------------------------------------------------------
# Operator runtimes (host-side wrappers over jitted shard_map step programs,
# implementing scheduler.OperatorRuntime)
# ---------------------------------------------------------------------------

class _DScanRT:
    def __init__(self, eng: "DistributedEngine", desc: OpDesc, out_q: _DQueue):
        self.e, self.desc, self.out_q = eng, desc, out_q
        self.label = desc.label()
        self.cursor = jax.device_put(jnp.zeros((eng.p,), jnp.int32), eng.sh(1))
        self.rounds_done = 0
        self.delta = desc.scan_epoch == "delta"
        if self.delta and eng.delta_adj is None:
            raise RuntimeError(
                "delta-seeded scan on a distributed engine with no applied "
                "update batch — call DistributedEngine.apply_updates first"
            )
        # Rounds derive from the *current* batch size: scan sources are padded
        # to a multiple of the configured batch, and recovery only ever halves
        # it, so the division stays exact on degraded re-runs.
        scan_len = eng.delta_scan_len if self.delta else eng.scan_len
        self.rounds = scan_len // eng.cfg.batch_size
        self.step = eng._build_scan_step(desc)
        self.query = ""

    def has_input(self) -> bool:
        return self.rounds_done < self.rounds

    def internal_pending(self) -> bool:
        return self.has_input()

    def output_free(self) -> int:
        return self.out_q.free()

    def required_slack(self) -> int:
        return self.e.cfg.batch_size

    def run_one(self) -> None:
        e = self.e
        e._inject(("queue-overflow", "shard-loss"), self.label, self.query)
        if self.delta:
            src, dst, totals = e.delta_src, e.delta_dst, e.delta_scan_totals
        else:
            src, dst, totals = e.src, e.dst, e.scan_totals
        buf, n = self.step(
            src, dst, totals, self.cursor, self.out_q.buf, self.out_q.n
        )
        self.out_q.set(buf, n)
        self.cursor = self.cursor + e.cfg.batch_size
        self.rounds_done += 1
        e.stats["rounds"] += 1


class _DExtendRT:
    """PULL-EXTEND / VERIFY: fetch (2 a2a) + intersect + optional steal (2 a2a)."""

    def __init__(self, eng: "DistributedEngine", desc: OpDesc, in_q: _DQueue,
                 out_q: _DQueue):
        self.e, self.desc, self.in_q, self.out_q = eng, desc, in_q, out_q
        self.label = desc.label()
        self.is_verify = desc.kind == "verify"
        self.delta = "old" in desc.ext_epochs
        if self.delta and eng.delta_adj is None:
            raise RuntimeError(
                "old-epoch extend/verify on a distributed engine with no "
                "applied update batch — call apply_updates first"
            )
        self.step = eng._build_extend_step(desc, self.is_verify)
        self._ref_step = None  # lazily-built unfused twin (kernel-fail path)
        self.query = ""
        # The steal all_to_all is statically elided when a batch's worst-case
        # output can't be split P ways (mirrors the out_w >= p trace guard).
        self.steal_traced = (
            not self.is_verify
            and eng.cfg.rebalance
            and eng.cfg.batch_size * eng.d_pad >= eng.p
        )

    def has_input(self) -> bool:
        return self.in_q.max_n > 0

    def internal_pending(self) -> bool:
        return False

    def output_free(self) -> int:
        return self.out_q.free()

    def required_slack(self) -> int:
        b = self.e.cfg.batch_size
        return b if self.is_verify else b * self.e.d_pad

    def run_one(self) -> None:
        e = self.e
        e._inject(("queue-overflow", "shard-loss"), self.label, self.query)
        step = self.step
        if (
            e.cfg.fused
            and not self.delta
            and e.cfg.faults is not None
            and e.cfg.faults.should_fire("kernel-fail", self.label)
        ):
            # One-shot graceful degradation: re-run this batch through the
            # unfused (ref-twin) step program — exact, just slower.
            e.stats["kernel_fallbacks"] += 1
            _log.warning("fused %s kernel failed at op=%s query=%s; "
                         "falling back to ref step",
                         "verify" if self.is_verify else "extend",
                         self.label, self.query)
            if self._ref_step is None:
                self._ref_step = e._build_extend_step(
                    self.desc, self.is_verify, fused_override=False
                )
            step = self._ref_step
        if self.delta:
            rem, buf, n, comm = step(
                e.delta_adj, e.adj, self.in_q.buf, self.in_q.n,
                self.out_q.buf, self.out_q.n,
            )
        else:
            rem, buf, n, comm = step(
                e.adj, self.in_q.buf, self.in_q.n, self.out_q.buf, self.out_q.n
            )
        self.in_q.set_n(rem)
        self.out_q.set(buf, n)
        fetched, stolen = (int(x) for x in np.asarray(jnp.sum(comm, axis=0)))
        e.stats["rounds"] += 1
        e.stats["a2a_calls"] += 2 + (2 if self.steal_traced else 0)
        e.stats["pulled_vids"] += fetched
        e.stats["pulled_bytes"] += fetched * (e.d_pad + 2) * 4
        e.stats["steal_rows"] += stolen
        e.stats["steal_bytes"] += stolen * self.out_q.width * 4


class _DJoinRT:
    """PUSH-JOIN: hash-shuffle both inputs by join key (all_to_all), buffer
    them in preallocated [P, CAP, K] side buffers, and — once the left branch
    has drained (the §5.4 barrier) — sort the left side once and stream the
    right side through local probes."""

    def __init__(self, eng: "DistributedEngine", desc: OpDesc,
                 left_q: _DQueue, right_q: _DQueue, out_q: _DQueue):
        self.e, self.desc = eng, desc
        self.left_q, self.right_q, self.out_q = left_q, right_q, out_q
        self.label = desc.label()
        jcap = eng.cfg.join_buffer_capacity
        shuffle_slack = eng.p * eng.cfg.batch_size
        self.lbuf = _DQueue(eng, left_q.width, shuffle_slack, capacity=jcap)
        self.rbuf = _DQueue(eng, right_q.width, shuffle_slack, capacity=jcap)
        self.lshuf = eng._build_shuffle_step(desc.key_left[0])
        self.rshuf = eng._build_shuffle_step(desc.key_right[0])
        self.prep = eng._build_prepare_step(desc.key_left)
        self.probe = eng._build_probe_step(desc)
        self._ref_probe = None  # lazily-built unfused probe (kernel-fail path)
        self.query = ""
        self._sorted: Optional[Tuple[jax.Array, jax.Array]] = None
        # installed by the engine: () -> bool, True once every ancestor of the
        # left input (and the left queue itself) has drained
        self.left_branch_done = lambda: True

    # -- scheduling interface ------------------------------------------------
    #
    # A join has three micro-operations with different output targets and
    # worst-case sizes (shuffle-left → lbuf, shuffle-right → rbuf, probe →
    # out_q), so capacity gating is internal: ``has_input`` reports pending
    # work, ``_runnable`` picks the next action that both has input *and*
    # fits, and output_free/required_slack degenerate to a 0/1 gate on it.
    # If work is pending but nothing fits (a genuinely overflowing side
    # buffer), the scheduler's stall guard raises — same contract as the
    # single-process queue-overflow error.

    def has_input(self) -> bool:
        return (
            self.left_q.max_n > 0
            or self.right_q.max_n > 0
            or (self.rbuf.max_n > 0 and self.left_branch_done())
        )

    def internal_pending(self) -> bool:
        # Rows shuffled but not yet probed keep this join's branch alive.
        return self.rbuf.max_n > 0

    def _runnable(self) -> Optional[str]:
        shuffle_slack = self.e.p * self.e.cfg.batch_size
        if self.left_q.max_n > 0 and self.lbuf.free() >= shuffle_slack:
            return "lshuf"
        # Probing precedes shuffle-right so the probe drains rbuf and unblocks
        # further shuffles; it never competes with shuffle-left because the
        # barrier implies the left queue has drained.
        if (
            self.rbuf.max_n > 0
            and self.left_branch_done()
            and self.out_q.free() >= self.e.cfg.join_out_capacity
        ):
            return "probe"
        if self.right_q.max_n > 0 and self.rbuf.free() >= shuffle_slack:
            return "rshuf"
        return None

    def output_free(self) -> int:
        return 1 if self._runnable() is not None else 0

    def required_slack(self) -> int:
        return 1

    # -- execution -----------------------------------------------------------

    def _shuffle(self, step, in_q: _DQueue, side: _DQueue) -> None:
        e = self.e
        rem, buf, n, moved = step(in_q.buf, in_q.n, side.buf, side.n)
        in_q.set_n(rem)
        side.set(buf, n)
        assert self._sorted is None or side is self.rbuf, (
            "left side grew after the join barrier released"
        )
        moved_rows = int(jnp.sum(moved))
        e.stats["rounds"] += 1
        e.stats["a2a_calls"] += 1
        e.stats["shuffle_rows"] += moved_rows
        e.stats["shuffle_bytes"] += moved_rows * side.width * 4

    def run_one(self) -> None:
        e = self.e
        e._inject(("join-overflow", "shard-loss"), self.label, self.query)
        a = self._runnable()
        if a == "lshuf":
            self._shuffle(self.lshuf, self.left_q, self.lbuf)
            return
        if a == "rshuf":
            self._shuffle(self.rshuf, self.right_q, self.rbuf)
            return
        if self._sorted is None:
            # Barrier released: external merge sort of the buffered branch.
            self._sorted = self.prep(self.lbuf.buf, self.lbuf.n)
        probe = self.probe
        if (
            e.cfg.fused
            and e.cfg.faults is not None
            and e.cfg.faults.should_fire("kernel-fail", self.label)
        ):
            # One-shot fallback to the binary-search probe (exact ref twin).
            e.stats["kernel_fallbacks"] += 1
            _log.warning("probe bounds kernel failed at op=%s query=%s; "
                         "using ref probe", self.label, self.query)
            if self._ref_probe is None:
                self._ref_probe = e._build_probe_step(
                    self.desc, use_kernel_override=False
                )
            probe = self._ref_probe
        out_buf, out_n, rem, overflow = probe(
            self._sorted[0], self._sorted[1], self.rbuf.buf, self.rbuf.n,
            self.out_q.buf, self.out_q.n,
        )
        if bool(jnp.any(overflow)):
            raise QueuePressure(
                "join-overflow",
                "distributed PUSH-JOIN probe exceeded join_out_capacity="
                f"{e.cfg.join_out_capacity} (results would be lost)",
                op=self.label, query=self.query,
            )
        self.rbuf.set_n(rem)
        self.out_q.set(out_buf, out_n)
        e.stats["rounds"] += 1
        e.stats["probe_batches"] += 1


class _DSinkRT:
    def __init__(self, eng: "DistributedEngine", desc: OpDesc, in_q: _DQueue):
        self.e, self.desc, self.in_q = eng, desc, in_q
        self.label = desc.label()
        self.count = 0

    def has_input(self) -> bool:
        return self.in_q.max_n > 0

    def internal_pending(self) -> bool:
        return False

    def output_free(self) -> int:
        return 1 << 62

    def required_slack(self) -> int:
        return 0

    def run_one(self) -> None:
        self.count += self.in_q.total()
        self.in_q.drain()
        self.e.stats["rounds"] += 1


class DistributedEngine:
    """SPMD execution of translated dataflows over a ``shard_map`` mesh axis.

    Runs *any* optimiser plan — including hybrid plans mixing PULL-EXTEND and
    PUSH-JOIN — entirely with device collectives; there is no single-process
    fallback. ``stats["engine"]`` is always ``"shard_map"`` and
    ``stats["joins"]`` counts the PUSH-JOINs executed distributedly.
    """

    def __init__(self, graph: Graph, mesh: Mesh, cfg: DistConfig | None = None):
        self.cfg = cfg or DistConfig()
        self.mesh = mesh
        self.axis = self.cfg.axis
        self.p = mesh.shape[self.axis]
        self.sh = lambda ndim: NamedSharding(mesh, P(self.axis, *([None] * (ndim - 1))))
        self._load_graph(graph)
        # Delta state (streaming): armed by apply_updates.
        self.delta_adj: Optional[jax.Array] = None
        self.delta_src = self.delta_dst = self.delta_scan_totals = None
        self.delta_scan_len = 0
        self.stats: Dict[str, object] = {}

    def _sharded_edge_lists(self, graph: Graph):
        """Per-shard directed edge lists padded to the max shard size — the
        scan source layout, shared by the full graph and the delta graph."""
        offsets = np.asarray(graph.offsets)
        deg_np = np.diff(offsets)
        src_all = np.repeat(np.arange(graph.num_vertices, dtype=np.int32), deg_np)
        dst_all = np.asarray(graph.nbrs, dtype=np.int32)
        owners = src_all % self.p
        b = self.cfg.batch_size
        max_e = max(int((owners == p).sum()) for p in range(self.p))
        max_e = max(b, ((max_e + b - 1) // b) * b)
        src = np.zeros((self.p, max_e), np.int32)
        dst = np.full((self.p, max_e), INVALID, np.int32)
        totals = np.zeros((self.p,), np.int32)
        for p in range(self.p):
            sel = owners == p
            n = int(sel.sum())
            src[p, :n] = src_all[sel]
            dst[p, :n] = dst_all[sel]
            totals[p] = n
        return (
            jax.device_put(jnp.asarray(src), self.sh(2)),
            jax.device_put(jnp.asarray(dst), self.sh(2)),
            jax.device_put(jnp.asarray(totals), self.sh(1)),
            max_e,
        )

    def _load_graph(self, graph: Graph) -> None:
        """(Re)partition and bind every graph-derived device array."""
        self.pg = partition_graph(graph, self.p)
        self.graph = graph
        self.v = graph.num_vertices
        self.d_pad = self.pg.d_pad
        self.adj = jax.device_put(self.pg.adj, self.sh(3))
        self.src, self.dst, self.scan_totals, self.scan_len = (
            self._sharded_edge_lists(graph)
        )

    # -- streaming updates (DESIGN.md §Delta-plans) ----------------------------

    def apply_updates(self, batch):
        """Apply an edge-insert batch on the distributed engine.

        The storage rebuild itself is row-local (graph/storage.apply_updates);
        the shard partition is then re-derived — vertex ownership is ``v % P``
        so ownership never moves, only the owners' padded rows change. The
        delta graph is kept two ways: its directed edges sharded by owner
        exactly like normal scan sources (delta scans are sharded scans), and
        its padded adjacency **replicated** on every shard for the old-epoch
        membership veto — delta batches are small, so replication is cheaper
        than a second fetch round per extend."""
        from repro.graph.storage import apply_updates as storage_apply_updates

        applied = storage_apply_updates(self.graph, batch)
        self._load_graph(applied.graph)
        delta = applied.delta
        self.delta_adj = jax.device_put(
            delta.padded.adj, NamedSharding(self.mesh, P())
        )
        (
            self.delta_src,
            self.delta_dst,
            self.delta_scan_totals,
            self.delta_scan_len,
        ) = self._sharded_edge_lists(delta)
        return applied

    # ------------------------------------------------------------------
    # shard-local pieces (inside shard_map; no leading P dim)
    # ------------------------------------------------------------------

    def _offshard_count(self, mask):
        """Number of True entries in a per-destination ``[P, ...]`` mask whose
        destination is not this shard — the cross-network share of an
        all_to_all, for traffic accounting."""
        me = jax.lax.axis_index(self.axis)
        dest = jnp.arange(self.p).reshape((self.p,) + (1,) * (mask.ndim - 1))
        return jnp.sum((mask & (dest != me)).astype(jnp.int32))

    def _fetch(self, adj, rows, valid_rows, ext):
        """Fetch stage: dedup needed vids, owner-routed exchange, return a
        sorted lookup table (vids, adjacency rows) plus the number of requests
        this shard routed to *other* shards (pull-traffic accounting)."""
        p, axis = self.p, self.axis
        vids = rows[:, list(ext)].reshape(-1)
        ok = (
            (vids != INVALID)
            & (vids >= 0)
            & jnp.repeat(valid_rows[:, None], len(ext), 1).reshape(-1)
        )
        r_cap = vids.shape[0]
        owner = jnp.where(ok, vids % p, p)
        key = jnp.where(ok, owner * self.v + vids, p * self.v)
        skey = jnp.sort(key)
        uniq = (skey < p * self.v) & jnp.concatenate(
            [jnp.ones((1,), bool), skey[1:] != skey[:-1]]
        )
        o_s = jnp.where(uniq, skey // self.v, p)
        v_s = jnp.where(uniq, skey % self.v, INVALID)
        cnt = jax.ops.segment_sum(uniq.astype(jnp.int32), o_s, num_segments=p + 1)[:p]
        offs = jnp.cumsum(cnt) - cnt
        rank = jnp.cumsum(uniq.astype(jnp.int32)) - 1
        slot = rank - jnp.take(
            jnp.concatenate([offs, jnp.zeros(1, jnp.int32)]), jnp.minimum(o_s, p)
        )
        reqs = jnp.full((p, r_cap), INVALID, jnp.int32).at[
            jnp.where(uniq, o_s, p), jnp.where(uniq, slot, r_cap)
        ].set(v_s, mode="drop")
        remote = self._offshard_count(reqs != INVALID)
        got = jax.lax.all_to_all(reqs, axis, split_axis=0, concat_axis=0, tiled=True)
        lid = jnp.clip(jnp.where(got != INVALID, got // p, 0), 0, adj.shape[0] - 1)
        served = jnp.take(adj, lid.reshape(-1), axis=0).reshape(p, r_cap, -1)
        served = jnp.where((got != INVALID)[:, :, None], served, INVALID)
        back = jax.lax.all_to_all(served, axis, split_axis=0, concat_axis=0, tiled=True)
        back_vids = reqs.reshape(-1)
        order = jnp.argsort(back_vids)
        return (
            jnp.take(back_vids, order),
            jnp.take(back.reshape(-1, adj.shape[-1]), order, axis=0),
            remote,
        )

    def _lookup(self, table_vids, table_rows, adj, vids):
        p = self.p
        me = jax.lax.axis_index(self.axis)
        ok = (vids != INVALID) & (vids >= 0)
        local = ok & ((vids % p) == me)
        lrows = jnp.take(
            adj, jnp.clip(jnp.where(ok, vids // p, 0), 0, adj.shape[0] - 1), axis=0
        )
        idx = jnp.clip(jnp.searchsorted(table_vids, vids), 0, table_vids.shape[0] - 1)
        hit = jnp.take(table_vids, idx) == vids
        rrows = jnp.take(table_rows, idx, axis=0)
        rows = jnp.where(local[:, None], lrows, jnp.where(hit[:, None], rrows, INVALID))
        return jnp.where(ok[:, None], rows, INVALID)

    def _fused_addressing(self, table_vids, adj, rows, ext):
        """The _lookup gather as fused-kernel slab addressing: tab0 = fetched
        remote table, tab1 = local adjacency. Returns (idx[2, B, E], sel, ok)
        with sel routing remote hits to the table and ok covering exactly the
        rows _lookup would return non-INVALID (local or fetched)."""
        p = self.p
        me = jax.lax.axis_index(self.axis)
        vids = rows[:, list(ext)]                       # [B, E]
        okv = (vids != INVALID) & (vids >= 0)
        local = okv & ((vids % p) == me)
        idx1 = jnp.clip(jnp.where(okv, vids // p, 0), 0, adj.shape[0] - 1)
        idx0 = jnp.clip(jnp.searchsorted(table_vids, vids), 0, table_vids.shape[0] - 1)
        hit = jnp.take(table_vids, idx0) == vids
        sel = (~local) & hit
        ok = okv & (local | hit)
        idx = jnp.stack([idx0.astype(jnp.int32), idx1.astype(jnp.int32)])
        return idx, sel.astype(jnp.int32), ok.astype(jnp.int32)

    # ------------------------------------------------------------------
    # jitted shard_map step programs
    # ------------------------------------------------------------------

    def _shardmap(self, f, n_in, n_out):
        ax = self.axis
        return jax.jit(
            shard_map(
                f,
                mesh=self.mesh,
                in_specs=tuple(P(ax) for _ in range(n_in)),
                out_specs=tuple(P(ax) for _ in range(n_out)) if n_out > 1 else P(ax),
                check_rep=False,
            )
        )

    def _build_scan_step(self, op: OpDesc):
        b = self.cfg.batch_size
        lt, gt = op.lt_positions, op.gt_positions

        def f(src, dst, total, cursor, qbuf, qn):
            rows, n = ops_mod.scan_batch(src[0], dst[0], cursor[0], total[0], b, lt, gt)
            buf, n2 = ops_mod.queue_append(qbuf[0], qn[0], rows, n)
            return buf[None], n2[None]

        return self._shardmap(f, 6, 2)

    def _build_extend_step(self, op: OpDesc, is_verify: bool,
                           fused_override: Optional[bool] = None):
        b = self.cfg.batch_size
        ext, lt, gt = op.ext, op.lt_positions, op.gt_positions
        vpos = op.verify_pos
        rebalance = self.cfg.rebalance
        fused, force_kernel = self.cfg.fused, self.cfg.force_kernel
        if fused_override is not None:
            fused = fused_override  # kernel-fail degradation builds a ref twin
        p = self.p
        # Old-epoch ops veto delta membership against the *replicated* delta
        # adjacency (spec P() below); the fused kernels know nothing of
        # epochs, so epoch-carrying ops always take the plain intersect path.
        old_mask = tuple(ep == "old" for ep in op.ext_epochs) or (False,) * len(ext)
        has_old = any(old_mask)
        if has_old:
            fused = False

        def f(delta_adj, adj3, in_buf, in_n, out_buf, out_n):
            adj = adj3[0]

            def delta_rows(vids):
                safe = jnp.clip(vids, 0, delta_adj.shape[0] - 1)
                r = jnp.take(delta_adj, safe, axis=0)
                ok = (vids >= 0) & (vids != INVALID)
                return jnp.where(ok[:, None], r, INVALID)

            rows, take, rem = ops_mod.queue_pop(in_buf[0], in_n[0], b)
            valid = jnp.arange(b) < take
            tv, tr, remote = self._fetch(adj, rows, valid, ext)
            stolen = jnp.zeros((), jnp.int32)
            k = rows.shape[1]
            if is_verify and fused:
                from repro.kernels.intersect import ops as ik

                idx, sel, okm = self._fused_addressing(tv, adj, rows, ext)
                mask = valid & ik.fused_verify(
                    tr, adj, idx, sel, okm, rows, vpos=vpos,
                    force_kernel=force_kernel,
                )
                new_rows, m = ops_mod.compact(rows, mask, b)
                out_w = b
            elif is_verify:
                target = rows[:, vpos : vpos + 1]
                mask = valid
                for d, is_old in zip(ext, old_mask):
                    other = self._lookup(tv, tr, adj, rows[:, d])
                    mask = mask & ops_mod.row_membership(other, target)[:, 0]
                    if is_old:
                        mask = mask & ~ops_mod.row_membership(
                            delta_rows(rows[:, d]), target
                        )[:, 0]
                new_rows, m = ops_mod.compact(rows, mask, b)
                out_w = b
            else:
                if fused:
                    from repro.kernels.intersect import ops as ik

                    idx, sel, okm = self._fused_addressing(tv, adj, rows, ext)
                    cands, mask = ik.fused_extend(
                        tr, adj, idx, sel, okm, rows, lt=lt, gt=gt,
                        force_kernel=force_kernel,
                    )
                    mask = mask & valid[:, None]
                else:
                    cands = self._lookup(tv, tr, adj, rows[:, ext[0]])
                    mask = (cands != INVALID) & valid[:, None]
                    if old_mask[0]:
                        mask = mask & ~ops_mod.row_membership(
                            delta_rows(rows[:, ext[0]]), cands
                        )
                    for d, is_old in zip(ext[1:], old_mask[1:]):
                        other = self._lookup(tv, tr, adj, rows[:, d])
                        mask = mask & ops_mod.row_membership(other, cands)
                        if is_old:
                            mask = mask & ~ops_mod.row_membership(
                                delta_rows(rows[:, d]), cands
                            )
                    for col in range(k):
                        mask = mask & (cands != rows[:, col : col + 1])
                    for pp in lt:
                        mask = mask & (cands < jnp.where(valid, rows[:, pp], -1)[:, None])
                    for pp in gt:
                        mask = mask & (cands > jnp.where(valid, rows[:, pp], INVALID)[:, None])
                d_pad = cands.shape[1]
                expanded = jnp.concatenate(
                    [jnp.broadcast_to(rows[:, None, :], (b, d_pad, k)), cands[:, :, None]],
                    axis=2,
                ).reshape(b * d_pad, k + 1)
                new_rows, m = ops_mod.compact(expanded, mask.reshape(-1), b * d_pad)
                out_w = b * d_pad
                k = k + 1
            if rebalance and not is_verify and out_w >= p:
                share = out_w // p
                chunks = new_rows[: share * p].reshape(p, share, k)
                cvalid = (jnp.arange(share * p) < m).reshape(p, share)
                stolen = self._offshard_count(cvalid)
                got = jax.lax.all_to_all(chunks, self.axis, split_axis=0, concat_axis=0, tiled=True)
                gvalid = jax.lax.all_to_all(cvalid, self.axis, split_axis=0, concat_axis=0, tiled=True)
                new_rows, m = ops_mod.compact(got.reshape(-1, k), gvalid.reshape(-1), out_w)
            buf, n2 = ops_mod.queue_append(out_buf[0], out_n[0], new_rows, m)
            comm = jnp.stack([remote, stolen])[None]  # [1, 2]
            return rem[None], buf[None], n2[None], comm

        ax = self.axis
        if has_old:
            # Replicated delta adjacency: spec P() — every shard reads the
            # whole (small) delta table for its old-epoch membership vetoes.
            return jax.jit(
                shard_map(
                    f,
                    mesh=self.mesh,
                    in_specs=(P(),) + tuple(P(ax) for _ in range(5)),
                    out_specs=tuple(P(ax) for _ in range(4)),
                    check_rep=False,
                )
            )

        def g(adj3, in_buf, in_n, out_buf, out_n):
            return f(
                jnp.full((1, 1), INVALID, jnp.int32), adj3, in_buf, in_n,
                out_buf, out_n,
            )

        return self._shardmap(g, 5, 4)

    def _build_shuffle_step(self, key_col: int):
        """Pop a batch from an input queue, hash-route each row to shard
        ``row[key_col] % P`` with one all_to_all, append arrivals to the join
        side buffer. Also returns the number of rows that crossed shards."""
        b = self.cfg.batch_size
        p = self.p

        def f(in_buf, in_n, side_buf, side_n):
            rows, take, rem = ops_mod.queue_pop(in_buf[0], in_n[0], b)
            valid = jnp.arange(b) < take
            send = ops_mod.partition_rows_by_key(rows, valid, rows[:, key_col], p)
            moved = self._offshard_count(send[:, :, 0] != INVALID)
            got = jax.lax.all_to_all(send, self.axis, split_axis=0, concat_axis=0, tiled=True)
            flat = got.reshape(p * b, rows.shape[1])
            packed, m = ops_mod.compact(flat, flat[:, 0] != INVALID, p * b)
            buf, n2 = ops_mod.queue_append(side_buf[0], side_n[0], packed, m)
            return rem[None], buf[None], n2[None], moved[None]

        return self._shardmap(f, 4, 4)

    def _build_prepare_step(self, key_cols: Tuple[int, ...]):
        def f(side_buf, side_n):
            keys, sorted_buf = ops_mod.join_prepare(side_buf[0], side_n[0], key_cols)
            return keys[None], sorted_buf[None]

        return self._shardmap(f, 2, 2)

    def _build_probe_step(self, op: OpDesc,
                          use_kernel_override: Optional[bool] = None):
        b = self.cfg.batch_size
        out_cap = self.cfg.join_out_capacity
        key_right, right_extra = op.key_right, op.right_extra
        cross_neq, cross_lt = op.cross_neq, op.cross_lt

        use_kernel, force_kernel = self.cfg.fused, self.cfg.force_kernel
        if use_kernel_override is not None:
            use_kernel = use_kernel_override

        def f(skeys, sbuf, r_buf, r_n, out_buf, out_n):
            rrows, take, rem = ops_mod.queue_pop(r_buf[0], r_n[0], b)
            out, m, overflow = ops_mod.join_probe(
                skeys[0], sbuf[0], rrows, take,
                key_right, right_extra, cross_neq, cross_lt, out_cap,
                use_kernel=use_kernel, force_kernel=force_kernel,
            )
            buf, n2 = ops_mod.queue_append(out_buf[0], out_n[0], out, m)
            return buf[None], n2[None], rem[None], overflow[None]

        return self._shardmap(f, 6, 4)

    # ------------------------------------------------------------------

    def _build_runtimes(
        self, flow: Dataflow, tenant_of_op: Optional[Tuple[int, ...]] = None
    ) -> List[object]:
        ops = flow.ops
        b = self.cfg.batch_size
        queues: Dict[int, _DQueue] = {}
        for i, op in enumerate(ops):
            if op.kind == "sink":
                continue
            slack = {
                "scan": b,
                "verify": b,
                "extend": b * self.d_pad,
                "join": self.cfg.join_out_capacity,
            }[op.kind]
            queues[i] = _DQueue(self, len(op.schema), slack)

        runtimes: List[object] = []
        for i, op in enumerate(ops):
            if op.kind == "scan":
                rt = _DScanRT(self, op, queues[i])
            elif op.kind in ("extend", "verify"):
                rt = _DExtendRT(self, op, queues[op.inputs[0]], queues[i])
            elif op.kind == "join":
                rt = _DJoinRT(
                    self, op, queues[op.inputs[0]], queues[op.inputs[1]], queues[i]
                )
            else:
                rt = _DSinkRT(self, op, queues[op.inputs[0]])
            runtimes.append(rt)

        # Tenant tags for mixed traffic (run_concurrent): every queue and
        # runtime of a merged flow carries its tenant id. Rows themselves
        # never mix queues — each [P, CAP, K] buffer belongs to exactly one
        # tenant's op — so the tag lives on the queue, not as a +1 row column
        # that would widen every shuffle for information the queue already
        # encodes (DESIGN.md §Graph-service).
        for i, rt in enumerate(runtimes):
            t = 0 if tenant_of_op is None else tenant_of_op[i]
            rt.tenant = t
            rt.query = flow.query_name
            if tenant_of_op is not None:
                rt.label = f"t{t}:{rt.label}"
            if i in queues:
                queues[i].tenant = t

        # Join barriers: probing may start only once every ancestor of the
        # left input has drained — no scans pending, no queued rows, no
        # unprobed rows inside ancestor joins.
        for i, op in enumerate(ops):
            if op.kind != "join":
                continue
            branch = (*flow.ancestors(op.inputs[0]), op.inputs[0])

            def make_done(branch=branch):
                def done() -> bool:
                    for j in branch:
                        if runtimes[j].internal_pending():
                            return False
                        if j in queues and queues[j].max_n > 0:
                            return False
                    return True
                return done

            runtimes[i].left_branch_done = make_done()
        return runtimes

    def run(
        self,
        query_or_plan: QueryGraph | ExecutionPlan | Dataflow,
        space: str = "huge",
    ) -> Tuple[int, Dict]:
        """Plan (if needed), translate, and execute on the mesh. Returns
        ``(count, stats)``; stats always reports ``engine="shard_map"`` — every
        operator, PUSH-JOIN included, ran with real collectives."""
        flow = self._to_flow(query_or_plan, space)
        sinks = flow.sink_indices()
        if len(sinks) != 1:
            raise ValueError(
                f"run() got a flow with {len(sinks)} sinks — merged multi-sink "
                "flows carry one result per source flow; use run_concurrent "
                "(per-tenant counts) or run_delta (delta unions) instead"
            )
        runtimes, st = self._execute(flow)
        sink = runtimes[sinks[0]]
        assert isinstance(sink, _DSinkRT)
        return sink.count, self.stats

    def run_concurrent(
        self,
        queries: List[QueryGraph | ExecutionPlan | Dataflow],
        space: str = "huge",
    ) -> Tuple[List[int], Dict]:
        """Serve N tenants' queries through ONE engine instance: the flows are
        merged into a single multi-sink DAG (dataflow.merge_flows) and one
        AdaptiveScheduler pass interleaves their SPMD steps — mixed traffic on
        shared collectives, with tenant-tagged queues/runtimes keeping results
        and accounting separable. Returns per-tenant counts in input order."""
        flows = [self._to_flow(q, space) for q in queries]
        merged, tenant_of_op = merge_flows(flows)
        verify_flow(merged)  # the merged multi-sink DAG must also be well-formed
        runtimes, st = self._execute(merged, tenant_of_op)
        counts = []
        for i in merged.sink_indices():
            sink = runtimes[i]
            assert isinstance(sink, _DSinkRT)
            counts.append(sink.count)
        self.stats["tenants"] = len(flows)
        self.stats["per_tenant_matches"] = list(counts)
        return counts, self.stats

    def run_delta(
        self,
        query_or_plan: QueryGraph | ExecutionPlan,
        space: str = "huge",
    ) -> Tuple[int, Dict]:
        """Count only the matches created by the last applied batch, SPMD.

        The delta-join decomposition (dataflow.delta_flows) is merged into one
        multi-sink DAG — delta scans are sharded by edge owner exactly like
        normal scans, old-epoch extends veto against the replicated delta
        adjacency — and executed by the same scheduler pass as run(). Returns
        the summed delta count (the union of the k flows is disjoint by the
        exactly-once rule) plus the usual traffic stats."""
        if self.delta_adj is None:
            raise RuntimeError(
                "run_delta before apply_updates: no delta batch is armed"
            )
        if isinstance(query_or_plan, QueryGraph):
            plan = optimal_plan(
                query_or_plan, GraphStats.from_graph(self.graph), self.p, space
            )
        elif isinstance(query_or_plan, ExecutionPlan):
            plan = query_or_plan
        else:
            raise TypeError(
                "run_delta needs a QueryGraph or ExecutionPlan (delta flows "
                "are derived from the query, not from an existing Dataflow)"
            )
        from repro.core.dataflow import delta_flows

        flows = delta_flows(plan)
        merged, tenant_of_op = merge_flows(flows)
        verify_flow(merged)
        runtimes, st = self._execute(merged, tenant_of_op)
        count = 0
        for i in merged.sink_indices():
            sink = runtimes[i]
            assert isinstance(sink, _DSinkRT)
            count += sink.count
        self.stats["delta_flows"] = len(flows)
        return count, self.stats

    def _to_flow(
        self, query_or_plan: QueryGraph | ExecutionPlan | Dataflow, space: str
    ) -> Dataflow:
        if isinstance(query_or_plan, Dataflow):
            flow = query_or_plan
        else:
            if isinstance(query_or_plan, QueryGraph):
                plan = optimal_plan(
                    query_or_plan, GraphStats.from_graph(self.graph), self.p, space
                )
            else:
                plan = query_or_plan
            flow = translate(plan)
        # Mandatory pre-flight: structural verification before any device
        # work (queue pricing is the single-host engine's concern).
        verify_flow(flow)
        return flow

    # -- fault injection (core/faults.py) --------------------------------------

    def _inject(self, kinds: Tuple[str, ...], op: str, query: str = "") -> None:
        """Probe the armed FaultPlan at an operator invocation and raise the
        matching structured fault (host-side only; never inside shard_map)."""
        fp = self.cfg.faults
        if fp is None:
            return
        for kind in kinds:
            if fp.should_fire(kind, op):
                if kind == "shard-loss":
                    raise ShardLoss(fp.seed % self.p, op=op, query=query)
                raise QueuePressure(kind, "injected fault", op=op, query=query)

    def _execute(
        self, flow: Dataflow, tenant_of_op: Optional[Tuple[int, ...]] = None
    ):
        """Build runtimes and drive one scheduler pass, with restart-based
        recovery (DESIGN.md §Fault-tolerance): SPMD execution is
        deterministic, so a recoverable fault rebuilds the runtimes — fresh
        queues, zero counts — and re-runs the whole flow, halving the batch
        on QueuePressure. The original config is restored on exit, so
        degradation never leaks across queries."""
        orig_cfg = self.cfg
        attempts = restarts = pressure = 0
        try:
            while True:
                # Release the previous run's runtimes (and device queues)
                # before allocating fresh ones, so back-to-back runs/retries
                # don't hold both sets.
                self._last_runtimes = None
                self.stats = {
                    "engine": "shard_map",
                    "shards": self.p,
                    "joins": flow.num_joins(),
                    "rounds": 0,
                    "a2a_calls": 0,
                    "pulled_vids": 0,
                    "pulled_bytes": 0,
                    "shuffle_rows": 0,
                    "shuffle_bytes": 0,
                    "steal_rows": 0,
                    "steal_bytes": 0,
                    "probe_batches": 0,
                    "kernel_fallbacks": 0,
                    "retries": attempts,
                    "restarts": restarts,
                    "pressure_events": pressure,
                }
                runtimes = self._build_runtimes(flow, tenant_of_op)
                self._last_runtimes = runtimes  # debugging / test introspection
                sched = AdaptiveScheduler(runtimes, dfs_bias=attempts > 0)
                try:
                    st = sched.run()
                except EnumerationFault as f:
                    if (
                        not orig_cfg.recover
                        or not f.recoverable
                        or attempts >= orig_cfg.max_retries
                    ):
                        raise
                    attempts += 1
                    if isinstance(f, ShardLoss):
                        restarts += 1
                        _log.warning(
                            "restarting after %s (attempt %d/%d)",
                            f, attempts, orig_cfg.max_retries,
                        )
                    else:
                        pressure += 1
                        nb = max(self.cfg.batch_size // 2,
                                 orig_cfg.min_batch_size)
                        if nb >= self.cfg.batch_size:
                            raise EnumerationFault(
                                f.kind,
                                "recovery ladder exhausted: batch already at "
                                f"floor {self.cfg.batch_size} (raise queue "
                                "capacities or min_batch_size)",
                                op=f.op, query=f.query,
                            ) from f
                        _log.warning(
                            "restarting after %s (attempt %d/%d): "
                            "batch %d -> %d", f, attempts,
                            orig_cfg.max_retries, self.cfg.batch_size, nb,
                        )
                        self.cfg = dataclasses.replace(self.cfg, batch_size=nb)
                    continue
                self.stats["sched_steps"] = st.steps
                self.stats["sched_backtracks"] = st.backtracks
                return runtimes, st
        finally:
            self.cfg = orig_cfg
