"""LRBU cache — least-recent-batch-used (paper Alg. 3), TPU adaptation.

The paper's LRBU achieves lock-free zero-copy access by (a) a single writer
during the *fetch* stage, (b) read-only access during *intersect*, and (c)
seal/release bracketing each batch. On TPU the cache is a **functional,
epoch-sealed, set-associative table**:

  * ``Seal(v)``    → touched entries get ``epoch[v] = current_epoch`` and are
                     never evicted within the batch (eviction picks min epoch,
                     and current-epoch entries are masked out);
  * ``Release()``  → ``current_epoch += 1`` — previously sealed entries become
                     the *most recently batched* (largest order), exactly the
                     ordered-set bookkeeping of Alg. 3 lines 11-14;
  * lock-freedom   → writes happen only in the fetch phase (one logical
                     writer); intersect reads an immutable value;
  * zero-copy      → the state is updated with buffer donation (in-place).

Set-associativity replaces the paper's hash map: a vertex may live only in
set ``vid % num_sets``; within a set the LRBU victim is the min-epoch way.
Two variants are provided: a *stats* cache (keys only — used by the single-
device engine to account communication bytes) and a *value* cache (keys +
adjacency slabs — used by the distributed engine to serve Eq. 2 locally).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.graph.storage import INVALID


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class LRBUState:
    keys: jax.Array        # int32[S, W] vertex ids (INVALID = empty)
    epoch: jax.Array       # int32[S, W] last batch in which the entry was sealed
    current_epoch: jax.Array  # int32[]
    values: jax.Array | None = None  # int32[S, W, D] adjacency slabs (value cache)
    degs: jax.Array | None = None    # int32[S, W]

    @property
    def num_sets(self) -> int:
        return self.keys.shape[0]

    @property
    def num_ways(self) -> int:
        return self.keys.shape[1]

    def tree_flatten(self):
        return (self.keys, self.epoch, self.current_epoch, self.values, self.degs), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_cache(capacity: int, ways: int = 4, d_pad: int | None = None) -> LRBUState:
    sets = max(1, capacity // ways)
    keys = jnp.full((sets, ways), INVALID, dtype=jnp.int32)
    epoch = jnp.full((sets, ways), -1, dtype=jnp.int32)
    values = None
    degs = None
    if d_pad is not None:
        values = jnp.full((sets, ways, d_pad), INVALID, dtype=jnp.int32)
        degs = jnp.zeros((sets, ways), dtype=jnp.int32)
    return LRBUState(keys=keys, epoch=epoch, current_epoch=jnp.int32(0), values=values, degs=degs)


# ---------------------------------------------------------------------------
# Pure cache ops (vectorised over a request batch)
# ---------------------------------------------------------------------------

def _locate(state: LRBUState, vids: jax.Array):
    """Return (set index, way index or -1) for each request vid."""
    sets = jnp.where(vids >= 0, vids % state.num_sets, 0)
    keys = jnp.take(state.keys, sets, axis=0)          # [N, W]
    hit_ways = keys == vids[:, None]
    way = jnp.argmax(hit_ways, axis=1)
    hit = jnp.any(hit_ways, axis=1) & (vids != INVALID) & (vids >= 0)
    return sets, jnp.where(hit, way, -1), hit


def _collision_rank(sets: jax.Array, active: jax.Array) -> jax.Array:
    """Rank of each active item among same-set items (0, 1, 2, …) so that
    multiple same-batch inserts into one set land in distinct ways."""
    n = sets.shape[0]
    key = jnp.where(active, sets, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key, stable=True)
    sk = jnp.take(key, order)
    new = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    gid = jnp.cumsum(new.astype(jnp.int32)) - 1
    start = jax.ops.segment_min(jnp.arange(n, dtype=jnp.int32), gid, num_segments=n)
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - jnp.take(start, gid)
    return jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)


@jax.jit
def fetch_update(state: LRBUState, vids: jax.Array):
    """The fetch stage of Alg. 4 against the cache, for a deduplicated batch of
    requested vertices: seal hits, insert misses (LRBU eviction), and advance
    the epoch (Release). Returns (state', hit_mask).

    ``vids`` must be deduplicated (INVALID-padded); duplicate set/way targets
    would otherwise race in the scatter — the engine dedups with sort+unique.
    """
    sets, way, hit = _locate(state, vids)

    # Seal hits: bump their epoch to the current batch so they cannot be
    # evicted by this batch's inserts.
    cur = state.current_epoch
    epoch = state.epoch.at[sets, jnp.where(hit, way, 0)].max(
        jnp.where(hit, cur, -1), mode="drop"
    )

    # Insert misses: victim = min-epoch way of the target set, excluding ways
    # sealed this batch (epoch == cur). If every way is sealed, the paper
    # allows bounded overflow — we emulate by (deterministically) overwriting
    # way 0 only when *all* ways are sealed, which matches the "no more than
    # one batch of overflow" bound.
    miss = (~hit) & (vids != INVALID) & (vids >= 0)
    set_epochs = jnp.take(epoch, sets, axis=0)              # [N, W]
    sealed = set_epochs >= cur
    masked = jnp.where(sealed, jnp.iinfo(jnp.int32).max, set_epochs)
    victim = jnp.argmin(masked, axis=1).astype(jnp.int32)
    victim = jnp.where(jnp.all(sealed, axis=1), 0, victim)
    # same-batch inserts into one set spread across ways (beyond W: bounded
    # overflow, last writer wins — the paper's one-batch overflow bound)
    victim = (victim + _collision_rank(sets, miss)) % state.num_ways

    tgt_set = jnp.where(miss, sets, state.num_sets)  # OOB drop for non-miss
    keys = state.keys.at[tgt_set, victim].set(vids, mode="drop")
    epoch = epoch.at[tgt_set, victim].set(cur, mode="drop")

    new_state = LRBUState(
        keys=keys,
        epoch=epoch,
        current_epoch=cur + 1,  # Release(): next batch outranks everything
        values=state.values,
        degs=state.degs,
    )
    return new_state, hit


@jax.jit
def fetch_update_values(state: LRBUState, vids: jax.Array, rows: jax.Array, degs: jax.Array):
    """Value-cache variant: also store fetched adjacency slabs for misses."""
    sets, way, hit = _locate(state, vids)
    cur = state.current_epoch
    epoch = state.epoch.at[sets, jnp.where(hit, way, 0)].max(
        jnp.where(hit, cur, -1), mode="drop"
    )
    miss = (~hit) & (vids != INVALID) & (vids >= 0)
    set_epochs = jnp.take(epoch, sets, axis=0)
    sealed = set_epochs >= cur
    masked = jnp.where(sealed, jnp.iinfo(jnp.int32).max, set_epochs)
    victim = jnp.argmin(masked, axis=1).astype(jnp.int32)
    victim = jnp.where(jnp.all(sealed, axis=1), 0, victim)
    victim = (victim + _collision_rank(sets, miss)) % state.num_ways
    tgt_set = jnp.where(miss, sets, state.num_sets)
    keys = state.keys.at[tgt_set, victim].set(vids, mode="drop")
    epoch2 = epoch.at[tgt_set, victim].set(cur, mode="drop")
    values = state.values.at[tgt_set, victim].set(rows, mode="drop")
    dd = state.degs.at[tgt_set, victim].set(degs, mode="drop")
    return (
        LRBUState(keys=keys, epoch=epoch2, current_epoch=cur + 1, values=values, degs=dd),
        hit,
    )


@jax.jit
def probe_indices(state: LRBUState, vids: jax.Array):
    """Read-only probe for the fused kernels: flat slab index of each vid into
    ``state.values.reshape(S*W, D)`` plus the hit mask. Misses return index 0
    with hit=False — the fused kernel's select mask routes them to the
    fallback table, so the placeholder row is never read."""
    sets, way, hit = _locate(state, vids)
    flat = sets * state.num_ways + jnp.where(hit, way, 0)
    return jnp.where(hit, flat, 0).astype(jnp.int32), hit


@jax.jit
def cache_lookup_values(state: LRBUState, vids: jax.Array):
    """Read-only Get() — zero-copy in the paper's sense: pure gather, no state
    mutation. Returns (rows[N, D], deg[N], hit[N])."""
    sets, way, hit = _locate(state, vids)
    safe_way = jnp.where(hit, way, 0)
    rows = state.values[sets, safe_way]
    degs = state.degs[sets, safe_way]
    rows = jnp.where(hit[:, None], rows, INVALID)
    degs = jnp.where(hit, degs, 0)
    return rows, degs, hit


# ---------------------------------------------------------------------------
# Baseline cache policies for Exp-6 (cache-design comparison)
# ---------------------------------------------------------------------------

@jax.jit
def fetch_update_lru(state: LRBUState, vids: jax.Array):
    """Classic LRU (per-access recency): identical structure, but *every hit*
    refreshes recency and eviction ignores sealing — the paper's 'LRU-Inf' /
    traditional baseline (here with finite capacity)."""
    sets, way, hit = _locate(state, vids)
    cur = state.current_epoch
    epoch = state.epoch.at[sets, jnp.where(hit, way, 0)].max(
        jnp.where(hit, cur, -1), mode="drop"
    )
    miss = (~hit) & (vids != INVALID) & (vids >= 0)
    set_epochs = jnp.take(epoch, sets, axis=0)
    victim = jnp.argmin(set_epochs, axis=1).astype(jnp.int32)
    tgt_set = jnp.where(miss, sets, state.num_sets)
    keys = state.keys.at[tgt_set, victim].set(vids, mode="drop")
    epoch = epoch.at[tgt_set, victim].set(cur, mode="drop")
    return LRBUState(keys, epoch, cur + 1, state.values, state.degs), hit


@jax.jit
def fetch_update_direct(state: LRBUState, vids: jax.Array):
    """Direct-mapped (1-way) baseline: always evict the colliding slot."""
    sets = jnp.where(vids >= 0, vids % state.num_sets, 0)
    keys0 = state.keys[:, 0]
    hit = (jnp.take(keys0, sets) == vids) & (vids != INVALID) & (vids >= 0)
    miss = (~hit) & (vids != INVALID) & (vids >= 0)
    tgt = jnp.where(miss, sets, state.num_sets)
    keys0 = keys0.at[tgt].set(vids, mode="drop")
    return (
        LRBUState(keys0[:, None], state.epoch, state.current_epoch + 1, state.values, state.degs),
        hit,
    )
