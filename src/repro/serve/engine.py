"""Batched serving engine: slot-based continuous batching over a static-shape
decode step.

A fixed number of slots share one jit-compiled ``decode_step``; finished
sequences (EOS or length) free their slot for queued requests. Prefill runs
per-request (right-aligned into the slot's cache region). Sampling is greedy
or temperature. This is deliberately static-shape everywhere so the same code
lowers on the production mesh (the decode_32k / long_500k dry-run cells lower
exactly this step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 1024
    batch_slots: int = 8
    temperature: float = 0.0
    eos_token: int = 1
    max_new_tokens: int = 64


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # [S] int32
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class BatchedServer:
    def __init__(self, cfg: T.ModelConfig, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos)
        )
        self._prefill = jax.jit(
            lambda p, b: T.prefill(cfg, p, b, scfg.max_len),
            static_argnames=(),
        )

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def run(self, requests: List[Request]) -> Dict:
        """Serve a list of requests in slot batches; returns throughput stats."""
        scfg = self.scfg
        t0 = time.perf_counter()
        total_new = 0
        key = jax.random.key(0)
        for base in range(0, len(requests), scfg.batch_slots):
            group = requests[base : base + scfg.batch_slots]
            b = len(group)
            # Latency is measured from the *group's* start, not the whole
            # run's t0 — otherwise every request in batch k inherits the wall
            # time of all earlier batches. (Per-request would start at enqueue
            # time; in this offline driver all requests arrive at once, so
            # group start is the first moment a request could be served.)
            g0 = time.perf_counter()
            # pad prompts to a common length (right aligned batch prefill)
            plen = max(len(r.prompt) for r in group)
            toks = np.zeros((b, plen), np.int32)
            for i, r in enumerate(group):
                toks[i, plen - len(r.prompt):] = r.prompt
            batch = {"tokens": jnp.asarray(toks)}
            cache, logits = self._prefill(self.params, batch)
            pos = plen
            key, k1 = jax.random.split(key)
            cur = self._sample(logits, k1)
            live = np.ones(b, bool)
            for i, r in enumerate(group):
                r.out_tokens.append(int(cur[i]))
            for step in range(scfg.max_new_tokens - 1):
                key, k1 = jax.random.split(key)
                logits, cache = self._decode(self.params, cache, cur[:, None], jnp.int32(pos))
                cur = self._sample(logits, k1)
                pos += 1
                for i, r in enumerate(group):
                    if live[i]:
                        tok = int(cur[i])
                        r.out_tokens.append(tok)
                        total_new += 1
                        if tok == scfg.eos_token or len(r.out_tokens) >= scfg.max_new_tokens:
                            live[i] = False
                            r.done = True
                            r.latency_s = time.perf_counter() - g0
                if not live.any():
                    break
            for r in group:
                if not r.done:
                    r.latency_s = time.perf_counter() - g0
                r.done = True
        dt = time.perf_counter() - t0
        return {
            "requests": len(requests),
            "new_tokens": total_new,
            "wall_s": dt,
            "tokens_per_s": total_new / max(dt, 1e-9),
        }
