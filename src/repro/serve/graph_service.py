"""Multi-tenant graph service: subgraph-matching-as-a-service.

N concurrent ``GraphQueryRequest``s (query graph + plan space + per-tenant
match/memory budgets) share ONE ``HugeEngine``: every admitted query becomes
an ``EngineSession`` owning a slot-slice of the device queues, leased from a
``QueueSlotPool`` whose total is the service-level Theorem 5.4 bound. One
scheduler pass per service ``tick`` drives a single ``AdaptiveScheduler``
over the *concatenation* of all active sessions' operator chains — the
BFS/DFS-adaptive policy interleaves runnable ops across tenants exactly as it
interleaves ops within one query, so the aggregate in-flight state stays
under the pool bound structurally (every queue is preallocated from the
lease). Finished queries drain their counts, release their cells, and the
admission queue refills the freed slots; requests that would exceed a
tenant's caps are rejected or queued instead of OOMing the engine.

Lifecycle of a request::

    submit() ──▶ QUEUED ──admission (pool lease + tenant caps)──▶ RUNNING
                   │                                                │
                   └──caps violated / queue full──▶ REJECTED        ├─▶ DONE
                                                                    └─▶ BUDGET_EXCEEDED

Latency is stamped per request — ``submitted_at`` at submit, ``finished_at``
at completion — so a request's latency never inherits the wall time of
batches served before it (the corrected pattern from serve/engine.py).

This is deliberately cooperative and single-threaded: a "tick" is the unit a
driving loop (launch/serve.py graph mode, benchmarks/exp_service_load.py)
calls as fast as it likes; all state lives in device queues and host
cursors, so the service is deterministic under any tick schedule.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic, FlowcheckError, errors
from repro.analysis.flowcheck import check_plan, check_query, verify_flow
from repro.core.cost import GraphStats
from repro.core.dataflow import Dataflow, delta_flows, merge_flows
from repro.core.optimizer import optimal_plan
from repro.core.plan import ExecutionPlan
from repro.core.engine import (
    EngineConfig,
    EngineSession,
    EngineStats,
    HugeEngine,
    QueueSlotPool,
    flow_queue_cells,
)
from repro.core.faults import EnumerationFault, FaultPlan, ShardLoss
from repro.core.query import PAPER_QUERIES, QueryGraph
from repro.core.scheduler import AdaptiveScheduler
from repro.graph.storage import Graph, GraphUpdateBatch

# Request states
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
REJECTED = "rejected"
BUDGET_EXCEEDED = "budget_exceeded"
CANCELLED = "cancelled"
FAILED = "failed"          # fault not recovered within the retry budget
TIMED_OUT = "timed_out"    # request deadline_s expired


@dataclasses.dataclass(frozen=True)
class TenantBudget:
    """Per-tenant caps. ``None`` means uncapped (subject to the global pool)."""

    max_matches: Optional[int] = None     # default per-query match budget
    max_queue_cells: Optional[int] = None # aggregate int32 cells across the
                                          #   tenant's admitted queries
    max_inflight: int = 8                 # queued + running queries


@dataclasses.dataclass
class GraphQueryRequest:
    """One tenant's enumeration request.

    ``query`` is a :class:`QueryGraph`, a name in ``PAPER_QUERIES`` (q1..q8
    / "triangle"), or — for tenants that bring their own planning — an
    :class:`ExecutionPlan` or raw :class:`Dataflow`; all forms pass the same
    flowcheck pre-flight at admission, so a malformed submission is rejected
    with structured diagnostics before any queue is leased. ``match_budget``
    stops the query once at least that many matches have been produced
    (batch-granular: the reported count may overshoot by up to the in-flight
    batches of the tick that crossed the line, never undershoot)."""

    tenant: str
    query: QueryGraph | ExecutionPlan | Dataflow | str
    space: str = "huge"
    match_budget: Optional[int] = None
    deadline_s: Optional[float] = None  # submit→finish wall-clock budget:
    #   past it the request times out (queued or running) instead of retrying


@dataclasses.dataclass
class QueryTicket:
    """Handle returned by ``submit``; the service mutates it in place."""

    id: int
    request: GraphQueryRequest
    status: str = QUEUED
    submitted_at: float = 0.0
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    count: int = 0
    queue_cells: int = 0
    stats: Optional[EngineStats] = None
    error: Optional[str] = None
    # Structured flowcheck findings when the request was rejected at
    # admission (rule ids + hints; see repro.analysis.diagnostics).
    diagnostics: Tuple[Diagnostic, ...] = ()
    # Fault-tolerance bookkeeping: how many admissions this ticket consumed,
    # the structured message of every fault it survived, and the earliest
    # tick at which a requeued attempt may re-admit (retry backoff).
    attempts: int = 0
    failures: List[str] = dataclasses.field(default_factory=list)
    not_before_tick: int = 0

    @property
    def latency_s(self) -> Optional[float]:
        """Submit→finish wall time, stamped per request (never inherited from
        earlier batches — the serve/engine.py latency fix, applied here)."""
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.submitted_at


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    # Global admission bound: total int32 cells all active sessions' device
    # queues may occupy — the service-level Theorem 5.4 budget the pool
    # enforces (DESIGN.md §Graph-service).
    total_queue_cells: int = 64 << 20
    # Slot-slice sizing per admitted query (passed to EngineSession; smaller
    # than the single-query engine defaults so many tenants fit the pool).
    queue_capacity: int = 1 << 12
    join_buffer_capacity: int = 1 << 14
    max_active: int = 8               # concurrent sessions (slots)
    admission_queue_len: int = 64     # beyond this, submit() rejects
    tick_steps: int = 32              # scheduler steps per active session per tick
    default_budget: TenantBudget = TenantBudget()
    # Fault tolerance (DESIGN.md §Fault-tolerance). Every N ticks each active
    # session is snapshotted; 0 disables checkpoints, in which case a
    # recoverable fault restarts the query from scratch via the retry path.
    checkpoint_every_ticks: int = 0
    max_retries: int = 2              # re-admissions after the first attempt
    retry_backoff_ticks: int = 2      # backoff = this * attempts ticks
    faults: Optional[FaultPlan] = None  # service-level injection (lease-oom)


@dataclasses.dataclass
class _Active:
    ticket: QueryTicket
    session: EngineSession


@dataclasses.dataclass
class StandingQuery:
    """A continuous subgraph query: registered once, answered per batch.

    The delta-join decomposition depends only on the query, so the merged
    multi-sink delta dataflow is translated and cached at registration;
    every ``apply_batch`` re-submits it as an ordinary request — standing
    deltas ride the *same* QueueSlotPool admission and Theorem-5.4 pricing
    as ad-hoc queries, they are not a privileged side channel. ``history``
    records one (ticket, count) outcome per applied batch."""

    id: int
    tenant: str
    query: QueryGraph
    plan: ExecutionPlan
    delta_flow: Dataflow                      # merged k-sink delta DAG
    match_budget: Optional[int] = None
    total_count: int = 0
    history: List[Tuple[QueryTicket, int]] = dataclasses.field(default_factory=list)


class GraphService:
    """Subgraph-matching-as-a-service over one shared :class:`HugeEngine`.

    >>> svc = GraphService(graph)
    >>> t = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    >>> svc.run_until_idle()
    >>> t.status, t.count
    """

    def __init__(
        self,
        graph: Graph,
        cfg: ServiceConfig | None = None,
        engine_cfg: EngineConfig | None = None,
        tenants: Dict[str, TenantBudget] | None = None,
    ):
        self.cfg = cfg or ServiceConfig()
        self.engine = HugeEngine(graph, engine_cfg)
        self.gstats = GraphStats.from_graph(graph)
        self.pool = QueueSlotPool(self.cfg.total_queue_cells)
        self.tenants: Dict[str, TenantBudget] = dict(tenants or {})
        self._tenant_cells: Dict[str, int] = {}
        self._tenant_inflight: Dict[str, int] = {}
        self._ids = itertools.count()
        self._planned: Dict[int, tuple] = {}  # ticket id -> (cells, flow)
        # ticket id -> (flow, session snapshot): the newest checkpoint of each
        # running query (taken every cfg.checkpoint_every_ticks ticks) and the
        # pinned resume state for tickets re-admitted via ``resume``.
        self._checkpoints: Dict[int, tuple] = {}
        self._restore_snap: Dict[int, tuple] = {}
        self.admission: deque[QueryTicket] = deque()
        self.active: List[_Active] = []
        self._rr = 0                      # round-robin offset for tick fairness
        self.ticks = 0
        self.peak_pool_cells = 0
        self.peak_inflight_rows = 0
        self.standing: List[StandingQuery] = []
        self.batches_applied = 0

    # -- tenant accounting ---------------------------------------------------

    def _budget(self, tenant: str) -> TenantBudget:
        return self.tenants.get(tenant, self.cfg.default_budget)

    def tenant_usage(self, tenant: str) -> Dict[str, int]:
        return {
            "inflight": self._tenant_inflight.get(tenant, 0),
            "queue_cells": self._tenant_cells.get(tenant, 0),
        }

    # -- submission / admission ----------------------------------------------

    def _resolve_query(self, req: GraphQueryRequest) -> QueryGraph | ExecutionPlan | Dataflow:
        if isinstance(req.query, (QueryGraph, ExecutionPlan, Dataflow)):
            return req.query
        if req.query in PAPER_QUERIES:
            return PAPER_QUERIES[req.query]
        raise KeyError(f"unknown query name: {req.query!r}")

    def submit(self, req: GraphQueryRequest) -> QueryTicket:
        """Accept a request into the admission queue (or reject it outright).

        Rejection happens at submit time only for violations no amount of
        waiting can cure or that protect the queue itself: an unknown query,
        a full admission queue, or a tenant over its inflight cap. Memory-cap
        checks happen at admission time, when the queues are actually sized."""
        ticket = QueryTicket(id=next(self._ids), request=req,
                            submitted_at=time.perf_counter())
        try:
            self._resolve_query(req)
        except KeyError as e:
            ticket.status = REJECTED
            ticket.error = str(e)
            ticket.finished_at = time.perf_counter()
            return ticket
        budget = self._budget(req.tenant)
        if self._tenant_inflight.get(req.tenant, 0) >= budget.max_inflight:
            ticket.status = REJECTED
            ticket.error = f"tenant {req.tenant!r} over max_inflight={budget.max_inflight}"
            ticket.finished_at = time.perf_counter()
            return ticket
        if len(self.admission) >= self.cfg.admission_queue_len:
            ticket.status = REJECTED
            ticket.error = "admission queue full"
            ticket.finished_at = time.perf_counter()
            return ticket
        self._tenant_inflight[req.tenant] = self._tenant_inflight.get(req.tenant, 0) + 1
        self.admission.append(ticket)
        return ticket

    def _price(self, ticket: QueryTicket):
        """Plan once, verify once, price once: ``(cells, flow)`` the
        request's session will lease/execute (cached so waiting tickets
        aren't re-planned every admission sweep).

        Raises :class:`FlowcheckError` when the submission fails static
        verification — query/plan checks for self-planned forms, then the
        full dataflow check — so ``_try_admit`` can reject with the rule
        ids *before* touching the slot pool."""
        if ticket.id not in self._planned:
            req = ticket.request
            target = self._resolve_query(req)
            if isinstance(target, QueryGraph):
                bad = errors(check_query(target))
                if bad:
                    raise FlowcheckError(bad)
            elif isinstance(target, ExecutionPlan):
                bad = errors(check_plan(target))
                if bad:
                    raise FlowcheckError(bad)
            flow = self.engine.to_flow(target, req.space, self.gstats)
            verify_flow(
                flow, cfg=self.engine.cfg, d_pad=self.engine.d_pad,
                queue_capacity=self.cfg.queue_capacity,
                join_buffer_capacity=self.cfg.join_buffer_capacity,
            )
            cells = flow_queue_cells(
                flow, self.engine.cfg, self.engine.d_pad,
                self.cfg.queue_capacity, self.cfg.join_buffer_capacity,
            )
            self._planned[ticket.id] = (cells, flow)
        return self._planned[ticket.id]

    def _try_admit(self) -> int:
        """First-fit admission sweep: walk the queue in arrival order, admit
        every request whose slot-slice fits the pool, its tenant's cell cap,
        and a free active slot. Requests that exceed their tenant's *absolute*
        cap (could never fit even on an idle service) are rejected."""
        admitted = 0
        still_waiting: deque[QueryTicket] = deque()
        fp = self.cfg.faults
        while self.admission:
            ticket = self.admission.popleft()
            if ticket.not_before_tick > self.ticks:
                still_waiting.append(ticket)  # retry backoff not elapsed
                continue
            if len(self.active) >= self.cfg.max_active:
                still_waiting.append(ticket)
                continue
            req = ticket.request
            budget = self._budget(req.tenant)
            try:
                cells, flow = self._price(ticket)
            except FlowcheckError as e:
                # Malformed submission: reject with the structured findings.
                # Nothing was leased, so the pool is untouched.
                ticket.diagnostics = e.diagnostics
                rules = ", ".join(sorted({d.rule for d in e.diagnostics}))
                self._reject(ticket, f"flowcheck rejected query ({rules}): {e}")
                continue
            if budget.max_queue_cells is not None and cells > budget.max_queue_cells:
                self._reject(ticket,
                             f"query needs {cells} cells > tenant cap "
                             f"{budget.max_queue_cells}")
                continue
            if cells > self.pool.total_cells:
                ticket.diagnostics = (Diagnostic(
                    "queue-over-pool",
                    f"flow preallocates {cells} int32 queue cells > service "
                    f"pool {self.pool.total_cells}",
                    hint="shrink queue/join-buffer capacities or split the query",
                ),)
                self._reject(ticket,
                             f"query needs {cells} cells > service pool "
                             f"{self.pool.total_cells}")
                continue
            used = self._tenant_cells.get(req.tenant, 0)
            if fp is not None and fp.should_fire("lease-oom", "admit"):
                # Injected transient allocator refusal: indistinguishable from
                # a momentarily full pool, so the ticket simply waits for the
                # next sweep (lease-oom is recoverable by construction).
                ticket.failures.append(
                    "[lease-oom] op=admit: injected transient lease refusal")
                # One-tick backoff, so run_until_idle's no-progress guard
                # sees the deferral as pending work, not a deadlock.
                ticket.not_before_tick = max(
                    ticket.not_before_tick, self.ticks + 1)
                still_waiting.append(ticket)
                continue
            if (
                budget.max_queue_cells is not None
                and used + cells > budget.max_queue_cells
            ) or not self.pool.try_lease(cells):
                still_waiting.append(ticket)  # fits eventually; wait
                continue
            # From here the lease is held: any failure building the session
            # must give the cells back or the pool leaks on every crash.
            try:
                pinned = self._restore_snap.get(ticket.id)
                if pinned is not None:
                    rflow, snap = pinned
                    session = EngineSession.restore(
                        self.engine, rflow, snap,
                        queue_capacity=self.cfg.queue_capacity,
                        join_buffer_capacity=self.cfg.join_buffer_capacity,
                    )
                else:
                    session = EngineSession(
                        self.engine, flow,
                        queue_capacity=self.cfg.queue_capacity,
                        join_buffer_capacity=self.cfg.join_buffer_capacity,
                    )
                assert session.queue_cells == cells, "admission pricing drifted"
            except BaseException:
                self.pool.release(cells)
                raise
            self._restore_snap.pop(ticket.id, None)
            ticket.attempts += 1
            ticket.queue_cells = cells
            ticket.admitted_at = time.perf_counter()
            ticket.status = RUNNING
            ticket.stats = session.stats
            self._tenant_cells[req.tenant] = used + cells
            self.active.append(_Active(ticket, session))
            self.peak_pool_cells = max(self.peak_pool_cells, self.pool.leased_cells)
            admitted += 1
        self.admission = still_waiting
        return admitted

    def _reject(self, ticket: QueryTicket, why: str) -> None:
        ticket.status = REJECTED
        ticket.error = why
        ticket.finished_at = time.perf_counter()
        self._release_inflight(ticket)

    def _release_inflight(self, ticket: QueryTicket) -> None:
        t = ticket.request.tenant
        self._tenant_inflight[t] = max(0, self._tenant_inflight.get(t, 0) - 1)

    # -- the service tick ------------------------------------------------------

    def _release_active(self, act: _Active) -> None:
        """Return an active session's lease, tenant cells, slot, and
        checkpoint. try/finally-audited: even if the pool raises (e.g. the
        over-release guard), the slot and per-tenant accounting are still
        unwound, so a fault can never strand a phantom active session."""
        ticket = act.ticket
        t = ticket.request.tenant
        try:
            self._tenant_cells[t] = max(
                0, self._tenant_cells.get(t, 0) - ticket.queue_cells)
            self.pool.release(ticket.queue_cells)
        finally:
            ticket.queue_cells = 0
            self._checkpoints.pop(ticket.id, None)
            if act in self.active:
                self.active.remove(act)

    def _finish(self, act: _Active, status: str) -> None:
        ticket = act.ticket
        ticket.count = act.session.stats.count
        ticket.status = status
        ticket.finished_at = time.perf_counter()
        self._planned.pop(ticket.id, None)
        try:
            self._release_active(act)
        finally:
            self._release_inflight(ticket)

    def _memory_probe(self):
        rows = sum(a.session.rows_in_flight() for a in self.active)
        nbytes = sum(a.session.bytes_in_flight() for a in self.active)
        self.peak_inflight_rows = max(self.peak_inflight_rows, rows)
        return rows, nbytes

    def tick(self) -> Dict[str, int]:
        """One service tick: admit what fits, run one shared scheduler pass
        over all active sessions (budgeted at ``tick_steps`` per session),
        then retire sessions that completed or crossed their match budget.

        A fault raised by any session's operator aborts only that session's
        tick share: the owning ticket is degraded in place (checkpoint
        restore at a smaller batch) or requeued/failed per the retry budget —
        the other tenants' sessions are untouched and resume next tick."""
        self.ticks += 1
        self._expire_deadlines()
        admitted = self._try_admit()
        steps = 0
        faulted = 0
        if self.active:
            # Rotate the concatenation order so no tenant permanently owns
            # the scheduler's starting cursor (round-robin fairness).
            order = self.active[self._rr % len(self.active):] + \
                self.active[: self._rr % len(self.active)]
            self._rr += 1
            chain = [rt for a in order for rt in a.session.chain]
            sched = AdaptiveScheduler(chain, memory_probe=self._memory_probe)
            try:
                st = sched.run(max_steps=self.cfg.tick_steps * len(self.active))
                steps = st.steps
            except EnumerationFault as f:
                steps = sched.stats.steps
                act = next(
                    (a for a in self.active if a.session is f.session), None)
                if act is None:
                    raise  # fault outside any active session: not ours to eat
                self._handle_fault(act, f)
                faulted = 1
        if (
            self.cfg.checkpoint_every_ticks > 0
            and self.ticks % self.cfg.checkpoint_every_ticks == 0
        ):
            for act in self.active:
                self._checkpoints[act.ticket.id] = (
                    act.session.flow, act.session.snapshot())
        completed = 0
        for act in list(self.active):
            req = act.ticket.request
            budget = req.match_budget
            if budget is None:
                budget = self._budget(req.tenant).max_matches
            if act.session.done():
                self._finish(act, DONE)
                completed += 1
            elif budget is not None and act.session.stats.count >= budget:
                self._finish(act, BUDGET_EXCEEDED)
                completed += 1
        if completed:
            admitted += self._try_admit()
        return {"admitted": admitted, "steps": steps, "completed": completed,
                "faulted": faulted,
                "active": len(self.active), "queued": len(self.admission)}

    # -- fault handling (DESIGN.md §Fault-tolerance) ---------------------------

    def _handle_fault(self, act: _Active, fault: EnumerationFault) -> None:
        """Degrade in place when possible, otherwise requeue or fail.

        Preference order: (1) a recoverable fault with a live checkpoint →
        restore this session from it at half the batch size (shard-loss: same
        batch — the replay is deterministic) with DFS-biased draining; the
        queue capacities are repriced identically so the ticket's lease is
        unchanged and no pool traffic occurs. (2) no checkpoint, or the
        degradation ladder bottomed out → release everything and requeue with
        backoff while the retry budget and deadline allow. (3) otherwise the
        ticket fails with the structured fault message."""
        ticket = act.ticket
        ticket.failures.append(str(fault))
        ckpt = self._checkpoints.get(ticket.id)
        ecfg = self.engine.cfg
        if fault.recoverable and ckpt is not None:
            rflow, snap = ckpt
            prev_batch = snap["batch_size"]
            shard_loss = isinstance(fault, ShardLoss)
            new_batch = prev_batch if shard_loss else max(
                prev_batch // 2, ecfg.min_batch_size)
            if shard_loss or new_batch < prev_batch:
                act.session = EngineSession.restore(
                    self.engine, rflow, snap, stats=act.session.stats,
                    queue_capacity=self.cfg.queue_capacity,
                    join_buffer_capacity=self.cfg.join_buffer_capacity,
                    batch_size=new_batch,
                    dfs_bias=not shard_loss,
                )
                act.session.stats.retries += 1
                if shard_loss:
                    act.session.stats.restarts += 1
                else:
                    act.session.stats.pressure_events += 1
                ticket.stats = act.session.stats
                # Re-checkpoint at the degraded batch so a repeat fault keeps
                # descending the ladder instead of retrying the same size.
                self._checkpoints[ticket.id] = (rflow, act.session.snapshot())
                return
        self._fail_attempt(act, fault)

    def _fail_attempt(self, act: _Active, fault: EnumerationFault) -> None:
        """Tear down a faulted session; requeue with backoff or fail the
        ticket. The lease/slot release is audited (``_release_active``), so a
        crashed query leaves the pool exactly where admission found it."""
        ticket = act.ticket
        now = time.perf_counter()
        req = ticket.request
        deadline_ok = (req.deadline_s is None
                       or now - ticket.submitted_at < req.deadline_s)
        ticket.count = act.session.stats.count  # partial progress, observable
        try:
            self._release_active(act)
        finally:
            if (fault.recoverable and deadline_ok
                    and ticket.attempts <= self.cfg.max_retries):
                ticket.status = QUEUED
                ticket.stats = None
                ticket.not_before_tick = (
                    self.ticks + self.cfg.retry_backoff_ticks * ticket.attempts)
                self.admission.append(ticket)
            else:
                ticket.status = FAILED
                ticket.error = str(fault)
                ticket.finished_at = now
                self._planned.pop(ticket.id, None)
                self._release_inflight(ticket)

    def _expire_deadlines(self) -> None:
        """Time out requests (queued or running) past their ``deadline_s``."""
        now = time.perf_counter()
        for act in list(self.active):
            d = act.ticket.request.deadline_s
            if d is not None and now - act.ticket.submitted_at > d:
                self._finish(act, TIMED_OUT)
                act.ticket.error = f"deadline_s={d} exceeded while running"
        if any(t.request.deadline_s is not None for t in self.admission):
            still: deque[QueryTicket] = deque()
            for t in self.admission:
                d = t.request.deadline_s
                if d is not None and now - t.submitted_at > d:
                    t.status = TIMED_OUT
                    t.error = f"deadline_s={d} exceeded before admission"
                    t.finished_at = now
                    self._planned.pop(t.id, None)
                    self._restore_snap.pop(t.id, None)
                    self._release_inflight(t)
                else:
                    still.append(t)
            self.admission = still

    def run_until_idle(self, max_ticks: int = 1_000_000) -> Dict[str, int]:
        """Tick until the admission queue and all slots drain."""
        done_total = 0
        for _ in range(max_ticks):
            if not self.active and not self.admission:
                break
            out = self.tick()
            done_total += out["completed"]
            backing_off = any(
                t.not_before_tick > self.ticks for t in self.admission)
            if (
                out["steps"] == 0 and out["admitted"] == 0
                and out["completed"] == 0 and out["faulted"] == 0
                and not backing_off and (self.active or self.admission)
            ):
                raise RuntimeError(
                    "graph service made no progress: active sessions are "
                    "deadlocked or queued work can never be admitted "
                    f"(active={len(self.active)}, queued={len(self.admission)})"
                )
        return {
            "ticks": self.ticks,
            "completed": done_total,
            "peak_pool_cells": self.peak_pool_cells,
            "peak_inflight_rows": self.peak_inflight_rows,
        }

    # -- crash recovery (DESIGN.md §Fault-tolerance) ---------------------------

    def snapshot(self) -> Dict[str, list]:
        """Host-side crash-recovery state: every standing-query definition
        (with its accumulated total) plus the newest checkpoint of each
        running query. Running queries only appear when
        ``cfg.checkpoint_every_ticks > 0`` — without periodic checkpoints
        there is nothing consistent to resume from and they restart."""
        running = []
        for act in self.active:
            ckpt = self._checkpoints.get(act.ticket.id)
            if ckpt is not None:
                running.append((act.ticket.request, ckpt[0], ckpt[1]))
        return {
            "standing": [
                (sq.tenant, sq.query, sq.match_budget, sq.total_count)
                for sq in self.standing
            ],
            "running": running,
        }

    @classmethod
    def restore(
        cls,
        graph: Graph,
        snap: Dict[str, list],
        cfg: ServiceConfig | None = None,
        engine_cfg: EngineConfig | None = None,
        tenants: Dict[str, TenantBudget] | None = None,
    ) -> "GraphService":
        """Rebuild a crashed service from ``snapshot()`` output: standing
        queries re-register (keeping their accumulated totals), and every
        checkpointed running query is re-admitted from its device-state
        snapshot via :meth:`resume`, so completed work is not repeated."""
        svc = cls(graph, cfg, engine_cfg, tenants)
        for tenant, query, match_budget, total in snap["standing"]:
            sq = svc.register_standing(tenant, query, match_budget=match_budget)
            sq.total_count = total
        for req, flow, sess_snap in snap["running"]:
            svc.resume(req, flow, sess_snap)
        return svc

    def resume(self, req: GraphQueryRequest, flow: Dataflow,
               sess_snap: Dict[str, object]) -> QueryTicket:
        """Re-admit an interrupted query from a checkpoint. The request rides
        the ordinary submit→admission path (inflight caps, pool pricing,
        first-fit sweep), but the priced flow is pinned and the session is
        built with :meth:`EngineSession.restore` at admission instead of
        fresh, resuming mid-enumeration with exactly-once counts."""
        ticket = self.submit(req)
        if ticket.status == QUEUED:
            cells = flow_queue_cells(
                flow, self.engine.cfg, self.engine.d_pad,
                self.cfg.queue_capacity, self.cfg.join_buffer_capacity,
            )
            self._planned[ticket.id] = (cells, flow)
            self._restore_snap[ticket.id] = (flow, sess_snap)
        return ticket

    # -- standing queries over streaming updates (DESIGN.md §Delta-plans) ------

    def register_standing(
        self,
        tenant: str,
        query: QueryGraph | ExecutionPlan | str,
        space: str = "huge",
        match_budget: Optional[int] = None,
    ) -> StandingQuery:
        """Register a continuous query; per-batch match deltas arrive via
        ``apply_batch``. The plan (and thus the delta decomposition) is fixed
        at registration time against the current graph statistics."""
        if isinstance(query, str):
            if query not in PAPER_QUERIES:
                raise KeyError(f"unknown query name: {query!r}")
            query = PAPER_QUERIES[query]
        if isinstance(query, QueryGraph):
            bad = errors(check_query(query))
            if bad:
                raise FlowcheckError(bad)
            plan = optimal_plan(
                query, self.gstats, self.engine.cfg.num_machines, space
            )
        elif isinstance(query, ExecutionPlan):
            bad = errors(check_plan(query))
            if bad:
                raise FlowcheckError(bad)
            plan = query
            query = plan.query
        else:
            raise TypeError(
                "standing queries need a QueryGraph/ExecutionPlan/name — the "
                "delta decomposition is derived from the query, not from a "
                "pre-translated Dataflow"
            )
        merged, _ = merge_flows(delta_flows(plan))
        verify_flow(
            merged, cfg=self.engine.cfg, d_pad=self.engine.d_pad,
            queue_capacity=self.cfg.queue_capacity,
            join_buffer_capacity=self.cfg.join_buffer_capacity,
        )
        sq = StandingQuery(
            id=next(self._ids), tenant=tenant, query=query, plan=plan,
            delta_flow=merged, match_budget=match_budget,
        )
        self.standing.append(sq)
        return sq

    def unregister_standing(self, sq: StandingQuery) -> bool:
        if sq in self.standing:
            self.standing.remove(sq)
            return True
        return False

    def apply_batch(self, batch: GraphUpdateBatch) -> Dict[str, object]:
        """Apply an edge batch and deliver each standing query's match delta.

        Consistency barrier first: in-flight ad-hoc queries are drained
        before the graph mutates (their sessions hold pre-batch adjacency
        state — partial matches extended against a mutated graph would be
        neither pre- nor post-batch semantics). Then the engine applies the
        update (row-local rebuild + cache drop), graph statistics are
        refreshed, and one delta ticket per standing query goes through the
        ordinary submit→admit→tick lifecycle, so concurrent standing tenants
        share the pool under the same pricing as ad-hoc traffic."""
        self.run_until_idle()
        applied = self.engine.apply_updates(batch)
        self.gstats = GraphStats.from_graph(self.engine.graph)
        self.batches_applied += 1
        tickets: List[Tuple[StandingQuery, QueryTicket]] = []
        for sq in self.standing:
            t = self.submit(GraphQueryRequest(
                tenant=sq.tenant, query=sq.delta_flow,
                match_budget=sq.match_budget,
            ))
            tickets.append((sq, t))
        self.run_until_idle()
        deltas: Dict[int, int] = {}
        for sq, t in tickets:
            count = t.count if t.status in (DONE, BUDGET_EXCEEDED) else 0
            sq.total_count += count
            sq.history.append((t, count))
            deltas[sq.id] = count
        return {
            "new_edges": applied.num_new_edges,
            "touched_vertices": int(applied.touched.shape[0]),
            "deltas": deltas,
            "tickets": [t for _, t in tickets],
        }

    def cancel(self, ticket: QueryTicket) -> bool:
        """Cancel a queued or running request; frees its slots immediately."""
        for act in self.active:
            if act.ticket is ticket:
                self._finish(act, CANCELLED)
                return True
        if ticket in self.admission:
            self.admission.remove(ticket)
            ticket.status = CANCELLED
            ticket.finished_at = time.perf_counter()
            self._release_inflight(ticket)
            return True
        return False
