"""Multi-tenant graph service: subgraph-matching-as-a-service.

N concurrent ``GraphQueryRequest``s (query graph + plan space + per-tenant
match/memory budgets) share ONE ``HugeEngine``: every admitted query becomes
an ``EngineSession`` owning a slot-slice of the device queues, leased from a
``QueueSlotPool`` whose total is the service-level Theorem 5.4 bound. One
scheduler pass per service ``tick`` drives a single ``AdaptiveScheduler``
over the *concatenation* of all active sessions' operator chains — the
BFS/DFS-adaptive policy interleaves runnable ops across tenants exactly as it
interleaves ops within one query, so the aggregate in-flight state stays
under the pool bound structurally (every queue is preallocated from the
lease). Finished queries drain their counts, release their cells, and the
admission queue refills the freed slots; requests that would exceed a
tenant's caps are rejected or queued instead of OOMing the engine.

Lifecycle of a request::

    submit() ──▶ QUEUED ──admission (pool lease + tenant caps)──▶ RUNNING
                   │                                                │
                   └──caps violated / queue full──▶ REJECTED        ├─▶ DONE
                                                                    └─▶ BUDGET_EXCEEDED

Latency is stamped per request — ``submitted_at`` at submit, ``finished_at``
at completion — so a request's latency never inherits the wall time of
batches served before it (the corrected pattern from serve/engine.py).

This is deliberately cooperative and single-threaded: a "tick" is the unit a
driving loop (launch/serve.py graph mode, benchmarks/exp_service_load.py)
calls as fast as it likes; all state lives in device queues and host
cursors, so the service is deterministic under any tick schedule.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic, FlowcheckError, errors
from repro.analysis.flowcheck import check_plan, check_query, verify_flow
from repro.core.cost import GraphStats
from repro.core.dataflow import Dataflow, delta_flows, merge_flows
from repro.core.optimizer import optimal_plan
from repro.core.plan import ExecutionPlan
from repro.core.engine import (
    EngineConfig,
    EngineSession,
    EngineStats,
    HugeEngine,
    QueueSlotPool,
    flow_queue_cells,
)
from repro.core.query import PAPER_QUERIES, QueryGraph
from repro.core.scheduler import AdaptiveScheduler
from repro.graph.storage import Graph, GraphUpdateBatch

# Request states
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
REJECTED = "rejected"
BUDGET_EXCEEDED = "budget_exceeded"
CANCELLED = "cancelled"


@dataclasses.dataclass(frozen=True)
class TenantBudget:
    """Per-tenant caps. ``None`` means uncapped (subject to the global pool)."""

    max_matches: Optional[int] = None     # default per-query match budget
    max_queue_cells: Optional[int] = None # aggregate int32 cells across the
                                          #   tenant's admitted queries
    max_inflight: int = 8                 # queued + running queries


@dataclasses.dataclass
class GraphQueryRequest:
    """One tenant's enumeration request.

    ``query`` is a :class:`QueryGraph`, a name in ``PAPER_QUERIES`` (q1..q8
    / "triangle"), or — for tenants that bring their own planning — an
    :class:`ExecutionPlan` or raw :class:`Dataflow`; all forms pass the same
    flowcheck pre-flight at admission, so a malformed submission is rejected
    with structured diagnostics before any queue is leased. ``match_budget``
    stops the query once at least that many matches have been produced
    (batch-granular: the reported count may overshoot by up to the in-flight
    batches of the tick that crossed the line, never undershoot)."""

    tenant: str
    query: QueryGraph | ExecutionPlan | Dataflow | str
    space: str = "huge"
    match_budget: Optional[int] = None


@dataclasses.dataclass
class QueryTicket:
    """Handle returned by ``submit``; the service mutates it in place."""

    id: int
    request: GraphQueryRequest
    status: str = QUEUED
    submitted_at: float = 0.0
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    count: int = 0
    queue_cells: int = 0
    stats: Optional[EngineStats] = None
    error: Optional[str] = None
    # Structured flowcheck findings when the request was rejected at
    # admission (rule ids + hints; see repro.analysis.diagnostics).
    diagnostics: Tuple[Diagnostic, ...] = ()

    @property
    def latency_s(self) -> Optional[float]:
        """Submit→finish wall time, stamped per request (never inherited from
        earlier batches — the serve/engine.py latency fix, applied here)."""
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.submitted_at


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    # Global admission bound: total int32 cells all active sessions' device
    # queues may occupy — the service-level Theorem 5.4 budget the pool
    # enforces (DESIGN.md §Graph-service).
    total_queue_cells: int = 64 << 20
    # Slot-slice sizing per admitted query (passed to EngineSession; smaller
    # than the single-query engine defaults so many tenants fit the pool).
    queue_capacity: int = 1 << 12
    join_buffer_capacity: int = 1 << 14
    max_active: int = 8               # concurrent sessions (slots)
    admission_queue_len: int = 64     # beyond this, submit() rejects
    tick_steps: int = 32              # scheduler steps per active session per tick
    default_budget: TenantBudget = TenantBudget()


@dataclasses.dataclass
class _Active:
    ticket: QueryTicket
    session: EngineSession


@dataclasses.dataclass
class StandingQuery:
    """A continuous subgraph query: registered once, answered per batch.

    The delta-join decomposition depends only on the query, so the merged
    multi-sink delta dataflow is translated and cached at registration;
    every ``apply_batch`` re-submits it as an ordinary request — standing
    deltas ride the *same* QueueSlotPool admission and Theorem-5.4 pricing
    as ad-hoc queries, they are not a privileged side channel. ``history``
    records one (ticket, count) outcome per applied batch."""

    id: int
    tenant: str
    query: QueryGraph
    plan: ExecutionPlan
    delta_flow: Dataflow                      # merged k-sink delta DAG
    match_budget: Optional[int] = None
    total_count: int = 0
    history: List[Tuple[QueryTicket, int]] = dataclasses.field(default_factory=list)


class GraphService:
    """Subgraph-matching-as-a-service over one shared :class:`HugeEngine`.

    >>> svc = GraphService(graph)
    >>> t = svc.submit(GraphQueryRequest(tenant="a", query="q1"))
    >>> svc.run_until_idle()
    >>> t.status, t.count
    """

    def __init__(
        self,
        graph: Graph,
        cfg: ServiceConfig | None = None,
        engine_cfg: EngineConfig | None = None,
        tenants: Dict[str, TenantBudget] | None = None,
    ):
        self.cfg = cfg or ServiceConfig()
        self.engine = HugeEngine(graph, engine_cfg)
        self.gstats = GraphStats.from_graph(graph)
        self.pool = QueueSlotPool(self.cfg.total_queue_cells)
        self.tenants: Dict[str, TenantBudget] = dict(tenants or {})
        self._tenant_cells: Dict[str, int] = {}
        self._tenant_inflight: Dict[str, int] = {}
        self._ids = itertools.count()
        self._planned: Dict[int, tuple] = {}  # ticket id -> (cells, flow)
        self.admission: deque[QueryTicket] = deque()
        self.active: List[_Active] = []
        self._rr = 0                      # round-robin offset for tick fairness
        self.ticks = 0
        self.peak_pool_cells = 0
        self.peak_inflight_rows = 0
        self.standing: List[StandingQuery] = []
        self.batches_applied = 0

    # -- tenant accounting ---------------------------------------------------

    def _budget(self, tenant: str) -> TenantBudget:
        return self.tenants.get(tenant, self.cfg.default_budget)

    def tenant_usage(self, tenant: str) -> Dict[str, int]:
        return {
            "inflight": self._tenant_inflight.get(tenant, 0),
            "queue_cells": self._tenant_cells.get(tenant, 0),
        }

    # -- submission / admission ----------------------------------------------

    def _resolve_query(self, req: GraphQueryRequest) -> QueryGraph | ExecutionPlan | Dataflow:
        if isinstance(req.query, (QueryGraph, ExecutionPlan, Dataflow)):
            return req.query
        if req.query in PAPER_QUERIES:
            return PAPER_QUERIES[req.query]
        raise KeyError(f"unknown query name: {req.query!r}")

    def submit(self, req: GraphQueryRequest) -> QueryTicket:
        """Accept a request into the admission queue (or reject it outright).

        Rejection happens at submit time only for violations no amount of
        waiting can cure or that protect the queue itself: an unknown query,
        a full admission queue, or a tenant over its inflight cap. Memory-cap
        checks happen at admission time, when the queues are actually sized."""
        ticket = QueryTicket(id=next(self._ids), request=req,
                            submitted_at=time.perf_counter())
        try:
            self._resolve_query(req)
        except KeyError as e:
            ticket.status = REJECTED
            ticket.error = str(e)
            ticket.finished_at = time.perf_counter()
            return ticket
        budget = self._budget(req.tenant)
        if self._tenant_inflight.get(req.tenant, 0) >= budget.max_inflight:
            ticket.status = REJECTED
            ticket.error = f"tenant {req.tenant!r} over max_inflight={budget.max_inflight}"
            ticket.finished_at = time.perf_counter()
            return ticket
        if len(self.admission) >= self.cfg.admission_queue_len:
            ticket.status = REJECTED
            ticket.error = "admission queue full"
            ticket.finished_at = time.perf_counter()
            return ticket
        self._tenant_inflight[req.tenant] = self._tenant_inflight.get(req.tenant, 0) + 1
        self.admission.append(ticket)
        return ticket

    def _price(self, ticket: QueryTicket):
        """Plan once, verify once, price once: ``(cells, flow)`` the
        request's session will lease/execute (cached so waiting tickets
        aren't re-planned every admission sweep).

        Raises :class:`FlowcheckError` when the submission fails static
        verification — query/plan checks for self-planned forms, then the
        full dataflow check — so ``_try_admit`` can reject with the rule
        ids *before* touching the slot pool."""
        if ticket.id not in self._planned:
            req = ticket.request
            target = self._resolve_query(req)
            if isinstance(target, QueryGraph):
                bad = errors(check_query(target))
                if bad:
                    raise FlowcheckError(bad)
            elif isinstance(target, ExecutionPlan):
                bad = errors(check_plan(target))
                if bad:
                    raise FlowcheckError(bad)
            flow = self.engine.to_flow(target, req.space, self.gstats)
            verify_flow(
                flow, cfg=self.engine.cfg, d_pad=self.engine.d_pad,
                queue_capacity=self.cfg.queue_capacity,
                join_buffer_capacity=self.cfg.join_buffer_capacity,
            )
            cells = flow_queue_cells(
                flow, self.engine.cfg, self.engine.d_pad,
                self.cfg.queue_capacity, self.cfg.join_buffer_capacity,
            )
            self._planned[ticket.id] = (cells, flow)
        return self._planned[ticket.id]

    def _try_admit(self) -> int:
        """First-fit admission sweep: walk the queue in arrival order, admit
        every request whose slot-slice fits the pool, its tenant's cell cap,
        and a free active slot. Requests that exceed their tenant's *absolute*
        cap (could never fit even on an idle service) are rejected."""
        admitted = 0
        still_waiting: deque[QueryTicket] = deque()
        while self.admission:
            ticket = self.admission.popleft()
            if len(self.active) >= self.cfg.max_active:
                still_waiting.append(ticket)
                continue
            req = ticket.request
            budget = self._budget(req.tenant)
            try:
                cells, flow = self._price(ticket)
            except FlowcheckError as e:
                # Malformed submission: reject with the structured findings.
                # Nothing was leased, so the pool is untouched.
                ticket.diagnostics = e.diagnostics
                rules = ", ".join(sorted({d.rule for d in e.diagnostics}))
                self._reject(ticket, f"flowcheck rejected query ({rules}): {e}")
                continue
            if budget.max_queue_cells is not None and cells > budget.max_queue_cells:
                self._reject(ticket,
                             f"query needs {cells} cells > tenant cap "
                             f"{budget.max_queue_cells}")
                continue
            if cells > self.pool.total_cells:
                ticket.diagnostics = (Diagnostic(
                    "queue-over-pool",
                    f"flow preallocates {cells} int32 queue cells > service "
                    f"pool {self.pool.total_cells}",
                    hint="shrink queue/join-buffer capacities or split the query",
                ),)
                self._reject(ticket,
                             f"query needs {cells} cells > service pool "
                             f"{self.pool.total_cells}")
                continue
            used = self._tenant_cells.get(req.tenant, 0)
            if (
                budget.max_queue_cells is not None
                and used + cells > budget.max_queue_cells
            ) or not self.pool.try_lease(cells):
                still_waiting.append(ticket)  # fits eventually; wait
                continue
            session = EngineSession(
                self.engine, flow,
                queue_capacity=self.cfg.queue_capacity,
                join_buffer_capacity=self.cfg.join_buffer_capacity,
            )
            assert session.queue_cells == cells, "admission pricing drifted"
            ticket.queue_cells = cells
            ticket.admitted_at = time.perf_counter()
            ticket.status = RUNNING
            ticket.stats = session.stats
            self._tenant_cells[req.tenant] = used + cells
            self.active.append(_Active(ticket, session))
            self.peak_pool_cells = max(self.peak_pool_cells, self.pool.leased_cells)
            admitted += 1
        self.admission = still_waiting
        return admitted

    def _reject(self, ticket: QueryTicket, why: str) -> None:
        ticket.status = REJECTED
        ticket.error = why
        ticket.finished_at = time.perf_counter()
        self._release_inflight(ticket)

    def _release_inflight(self, ticket: QueryTicket) -> None:
        t = ticket.request.tenant
        self._tenant_inflight[t] = max(0, self._tenant_inflight.get(t, 0) - 1)

    # -- the service tick ------------------------------------------------------

    def _finish(self, act: _Active, status: str) -> None:
        ticket = act.ticket
        ticket.count = act.session.stats.count
        ticket.status = status
        ticket.finished_at = time.perf_counter()
        self._planned.pop(ticket.id, None)
        t = ticket.request.tenant
        self._tenant_cells[t] = max(0, self._tenant_cells.get(t, 0) - ticket.queue_cells)
        self.pool.release(ticket.queue_cells)
        self._release_inflight(ticket)
        self.active.remove(act)

    def _memory_probe(self):
        rows = sum(a.session.rows_in_flight() for a in self.active)
        nbytes = sum(a.session.bytes_in_flight() for a in self.active)
        self.peak_inflight_rows = max(self.peak_inflight_rows, rows)
        return rows, nbytes

    def tick(self) -> Dict[str, int]:
        """One service tick: admit what fits, run one shared scheduler pass
        over all active sessions (budgeted at ``tick_steps`` per session),
        then retire sessions that completed or crossed their match budget."""
        self.ticks += 1
        admitted = self._try_admit()
        steps = 0
        if self.active:
            # Rotate the concatenation order so no tenant permanently owns
            # the scheduler's starting cursor (round-robin fairness).
            order = self.active[self._rr % len(self.active):] + \
                self.active[: self._rr % len(self.active)]
            self._rr += 1
            chain = [rt for a in order for rt in a.session.chain]
            sched = AdaptiveScheduler(chain, memory_probe=self._memory_probe)
            st = sched.run(max_steps=self.cfg.tick_steps * len(self.active))
            steps = st.steps
        completed = 0
        for act in list(self.active):
            req = act.ticket.request
            budget = req.match_budget
            if budget is None:
                budget = self._budget(req.tenant).max_matches
            if act.session.done():
                self._finish(act, DONE)
                completed += 1
            elif budget is not None and act.session.stats.count >= budget:
                self._finish(act, BUDGET_EXCEEDED)
                completed += 1
        if completed:
            admitted += self._try_admit()
        return {"admitted": admitted, "steps": steps, "completed": completed,
                "active": len(self.active), "queued": len(self.admission)}

    def run_until_idle(self, max_ticks: int = 1_000_000) -> Dict[str, int]:
        """Tick until the admission queue and all slots drain."""
        done_total = 0
        for _ in range(max_ticks):
            if not self.active and not self.admission:
                break
            out = self.tick()
            done_total += out["completed"]
            if (
                out["steps"] == 0 and out["admitted"] == 0
                and out["completed"] == 0 and (self.active or self.admission)
            ):
                raise RuntimeError(
                    "graph service made no progress: active sessions are "
                    "deadlocked or queued work can never be admitted "
                    f"(active={len(self.active)}, queued={len(self.admission)})"
                )
        return {
            "ticks": self.ticks,
            "completed": done_total,
            "peak_pool_cells": self.peak_pool_cells,
            "peak_inflight_rows": self.peak_inflight_rows,
        }

    # -- standing queries over streaming updates (DESIGN.md §Delta-plans) ------

    def register_standing(
        self,
        tenant: str,
        query: QueryGraph | ExecutionPlan | str,
        space: str = "huge",
        match_budget: Optional[int] = None,
    ) -> StandingQuery:
        """Register a continuous query; per-batch match deltas arrive via
        ``apply_batch``. The plan (and thus the delta decomposition) is fixed
        at registration time against the current graph statistics."""
        if isinstance(query, str):
            if query not in PAPER_QUERIES:
                raise KeyError(f"unknown query name: {query!r}")
            query = PAPER_QUERIES[query]
        if isinstance(query, QueryGraph):
            bad = errors(check_query(query))
            if bad:
                raise FlowcheckError(bad)
            plan = optimal_plan(
                query, self.gstats, self.engine.cfg.num_machines, space
            )
        elif isinstance(query, ExecutionPlan):
            bad = errors(check_plan(query))
            if bad:
                raise FlowcheckError(bad)
            plan = query
            query = plan.query
        else:
            raise TypeError(
                "standing queries need a QueryGraph/ExecutionPlan/name — the "
                "delta decomposition is derived from the query, not from a "
                "pre-translated Dataflow"
            )
        merged, _ = merge_flows(delta_flows(plan))
        verify_flow(
            merged, cfg=self.engine.cfg, d_pad=self.engine.d_pad,
            queue_capacity=self.cfg.queue_capacity,
            join_buffer_capacity=self.cfg.join_buffer_capacity,
        )
        sq = StandingQuery(
            id=next(self._ids), tenant=tenant, query=query, plan=plan,
            delta_flow=merged, match_budget=match_budget,
        )
        self.standing.append(sq)
        return sq

    def unregister_standing(self, sq: StandingQuery) -> bool:
        if sq in self.standing:
            self.standing.remove(sq)
            return True
        return False

    def apply_batch(self, batch: GraphUpdateBatch) -> Dict[str, object]:
        """Apply an edge batch and deliver each standing query's match delta.

        Consistency barrier first: in-flight ad-hoc queries are drained
        before the graph mutates (their sessions hold pre-batch adjacency
        state — partial matches extended against a mutated graph would be
        neither pre- nor post-batch semantics). Then the engine applies the
        update (row-local rebuild + cache drop), graph statistics are
        refreshed, and one delta ticket per standing query goes through the
        ordinary submit→admit→tick lifecycle, so concurrent standing tenants
        share the pool under the same pricing as ad-hoc traffic."""
        self.run_until_idle()
        applied = self.engine.apply_updates(batch)
        self.gstats = GraphStats.from_graph(self.engine.graph)
        self.batches_applied += 1
        tickets: List[Tuple[StandingQuery, QueryTicket]] = []
        for sq in self.standing:
            t = self.submit(GraphQueryRequest(
                tenant=sq.tenant, query=sq.delta_flow,
                match_budget=sq.match_budget,
            ))
            tickets.append((sq, t))
        self.run_until_idle()
        deltas: Dict[int, int] = {}
        for sq, t in tickets:
            count = t.count if t.status in (DONE, BUDGET_EXCEEDED) else 0
            sq.total_count += count
            sq.history.append((t, count))
            deltas[sq.id] = count
        return {
            "new_edges": applied.num_new_edges,
            "touched_vertices": int(applied.touched.shape[0]),
            "deltas": deltas,
            "tickets": [t for _, t in tickets],
        }

    def cancel(self, ticket: QueryTicket) -> bool:
        """Cancel a queued or running request; frees its slots immediately."""
        for act in self.active:
            if act.ticket is ticket:
                self._finish(act, CANCELLED)
                return True
        if ticket in self.admission:
            self.admission.remove(ticket)
            ticket.status = CANCELLED
            ticket.finished_at = time.perf_counter()
            self._release_inflight(ticket)
            return True
        return False
