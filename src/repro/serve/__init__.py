from repro.serve.engine import ServeConfig, BatchedServer

__all__ = ["ServeConfig", "BatchedServer"]
