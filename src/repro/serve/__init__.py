from repro.serve.engine import ServeConfig, BatchedServer
from repro.serve.graph_service import (
    GraphQueryRequest,
    GraphService,
    QueryTicket,
    ServiceConfig,
    TenantBudget,
)

__all__ = [
    "ServeConfig", "BatchedServer",
    "GraphService", "GraphQueryRequest", "QueryTicket",
    "ServiceConfig", "TenantBudget",
]
