"""Mixture-of-Experts layer with HUGE push/pull-hybrid dispatch.

The paper's core physical-planning insight (Eq. 3 / Remark 3.1) applied to the
expert-parallel join between routed tokens and expert weights:

  push → shuffle the routed tokens onto the expert shards with an explicit
         ``all_to_all`` over the EP axis (the paper's pushing hash join:
         intermediate results keyed by expert id cross the network);
  pull → ``all_gather`` the expert weights onto the token shards and compute
         locally (the paper's PULL-EXTEND: fetch the operand data, which is
         bounded by the "graph" size — here 3·E·d·ff weights — independent of
         how many tokens are in flight).

Both modes compute identical values; only the collective schedule differs.
``core.hybrid_comm.moe_dispatch_mode`` picks the cheaper one per (arch ×
shape) at plan time, exactly like the paper's optimiser configures each join.

Experts are sharded ``[E, d, ff] = P("data", None, "model")`` (EP over the
data axis, TP over the model axis). Implementation is an explicit shard_map:
dispatch is sort-based (argsort by expert, capacity-bounded scatter), so no
GShard dense-dispatch einsum FLOPs.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.layers import dense_init
from repro.models.sharding import active_mesh, axis_size, batch_axes, pspec, shard


def moe_init(key, d_model: int, d_ff: int, num_experts: int, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d_model, num_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (num_experts, d_model, d_ff), dtype),
        "w_up": dense_init(ks[2], (num_experts, d_model, d_ff), dtype),
        "w_down": dense_init(ks[3], (num_experts, d_ff, d_model), dtype),
    }


def _route(xt, router, experts_per_token):
    """Top-k routing. Returns (gates [T,K] f32, idx [T,K] i32)."""
    logits = xt.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def _positions_by_expert(idx_flat: jax.Array, num_experts: int):
    """Sort-based per-expert slot assignment: pos[i] = rank of i within its
    expert (stable in token order)."""
    n = idx_flat.shape[0]
    order = jnp.argsort(idx_flat, stable=True)
    sorted_e = jnp.take(idx_flat, order)
    start = jnp.searchsorted(sorted_e, jnp.arange(num_experts, dtype=idx_flat.dtype))
    rank = jnp.arange(n, dtype=jnp.int32) - jnp.take(start, sorted_e).astype(jnp.int32)
    pos = jnp.zeros((n,), jnp.int32).at[order].set(rank)
    return pos


def _expert_ffn(ex, wg, wu, wd, tp_axis: str | None):
    """ex [E_loc, C, d] @ per-expert FFN (ff possibly TP-sharded).

    (A forced-bf16-boundary variant was tried and REFUTED in §Perf qwen3
    iteration 1 — no wire saving, real precision cost — so compute follows
    the model dtype.)"""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex, wg)) * jnp.einsum("ecd,edf->ecf", ex, wu)
    out = jnp.einsum("ecf,efd->ecd", h, wd)
    if tp_axis is not None:
        out = jax.lax.psum(out, tp_axis)
    return out


def _dispatch_local(xt, gates, idx, cap, num_experts):
    """Build [E, cap, d] buckets + bookkeeping for the combine."""
    t, k = idx.shape
    d = xt.shape[-1]
    idx_flat = idx.reshape(-1)
    pos = _positions_by_expert(idx_flat, num_experts)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)  # cap = OOB → dropped
    tok = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    buckets = jnp.zeros((num_experts, cap, d), xt.dtype).at[idx_flat, slot].set(
        jnp.take(xt, tok, axis=0), mode="drop"
    )
    return buckets, (idx_flat, slot, keep, tok)

def _combine_local(expert_out, gates, book, t):
    idx_flat, slot, keep, tok = book
    vals = expert_out[idx_flat, jnp.clip(slot, 0, expert_out.shape[1] - 1)]
    vals = vals * (gates.reshape(-1)[:, None] * keep[:, None]).astype(vals.dtype)
    d = expert_out.shape[-1]
    return jnp.zeros((t, d), vals.dtype).at[tok].add(vals)


def moe_block(
    params: Dict,
    x: jax.Array,                 # [B, S, D]
    *,
    experts_per_token: int,
    capacity_factor: float = 1.25,
    comm_mode: str = "auto",      # "push" | "pull" | "local"
) -> jax.Array:
    b, s, d = x.shape
    e = params["router"].shape[1]
    mesh = active_mesh()
    ep = axis_size("data") * axis_size("pod")
    if mesh is None or ep == 1 or comm_mode == "local":
        return _moe_local(params, x, experts_per_token, capacity_factor)
    if (b * s) % ep != 0:
        # Tokens cannot shard over the EP axis (e.g. batch-1 long-context
        # decode): tokens stay replicated, weights are pulled — exactly the
        # regime where Remark 3.1 says pulling wins anyway.
        return _moe_pull(params, x, experts_per_token, capacity_factor, mesh,
                         replicated_tokens=True)
    if comm_mode == "pull":
        return _moe_pull(params, x, experts_per_token, capacity_factor, mesh)
    return _moe_push(params, x, experts_per_token, capacity_factor, mesh)


# -- single-shard path (smoke tests / 1-device) ------------------------------

def _capacity(n_routed: int, e: int, capacity_factor: float) -> int:
    """Per-expert capacity. Small batches (decode, smoke tests) get lossless
    capacity so no token is ever dropped; large training batches use the
    standard capacity-factor bound."""
    if n_routed <= 8192:
        return n_routed
    return max(1, int(n_routed * capacity_factor / e) + 1)


def _moe_local(params, x, experts_per_token, capacity_factor):
    b, s, d = x.shape
    e = params["router"].shape[1]
    t = b * s
    xt = x.reshape(t, d)
    gates, idx = _route(xt, params["router"], experts_per_token)
    cap = _capacity(t * experts_per_token, e, capacity_factor)
    buckets, book = _dispatch_local(xt, gates, idx, cap, e)
    out = _expert_ffn(buckets, params["w_gate"], params["w_up"], params["w_down"], None)
    return _combine_local(out, gates, book, t).reshape(b, s, d)


# -- PUSH: all_to_all routed tokens over the EP axis --------------------------

def _ep_axes(e: int, mesh):
    """Largest suffix of (pod, data) whose size divides the expert count —
    experts shard over it; any dropped leading axis holds DP replicas."""
    axes = batch_axes()
    for i in range(len(axes) + 1):
        cand = axes[i:]
        size = 1
        for a in cand:
            size *= mesh.shape[a]
        if cand and e % size == 0:
            return cand, size
    return (), 1


def _moe_push(params, x, experts_per_token, capacity_factor, mesh):
    b, s, d = x.shape
    e = params["router"].shape[1]
    ep_axes, ep = _ep_axes(e, mesh)
    if not ep_axes:
        return _moe_pull(params, x, experts_per_token, capacity_factor, mesh)
    tp = "model" if "model" in mesh.axis_names else None
    e_loc = e // ep

    def f(xt, router, wg, wu, wd):
        # xt [T_loc, d]; wg [E_loc, d, ff_loc]
        t_loc = xt.shape[0]
        gates, idx = _route(xt, router, experts_per_token)
        n = t_loc * experts_per_token
        cap = _capacity(n, e, capacity_factor)
        idx_flat = idx.reshape(-1)
        pos = _positions_by_expert(idx_flat, e)
        keep = pos < cap
        slot = jnp.where(keep, pos, cap)
        tok = jnp.broadcast_to(
            jnp.arange(t_loc)[:, None], (t_loc, experts_per_token)
        ).reshape(-1)
        send = jnp.zeros((e, cap, d), xt.dtype).at[idx_flat, slot].set(
            jnp.take(xt, tok, axis=0), mode="drop"
        )
        # [E, cap, d] → [EP, E_loc, cap, d]; shuffle shard i's slice to expert
        # owner i (the pushing hash join). ep_axes is the (pod, data) product,
        # pod-major — matching the expert sharding order of the weights.
        send = send.reshape(ep, e_loc, cap, d)
        recv = jax.lax.all_to_all(send, ep_axes, split_axis=0, concat_axis=0, tiled=True)
        ex = jnp.swapaxes(recv.reshape(ep, e_loc, cap, d), 0, 1).reshape(e_loc, ep * cap, d)
        # TP psum deferred past the (linear) combine: reducing the [E, cap, d]
        # buckets costs cap·E/T ≈ topk·capacity_factor ≈ 10× more wire than
        # reducing the combined [T_loc, d] tokens (§Perf qwen3 iteration 2).
        out = _expert_ffn(ex, wg, wu, wd, None)
        back = jnp.swapaxes(out.reshape(e_loc, ep, cap, d), 0, 1).reshape(ep * e_loc, cap, d)
        got = jax.lax.all_to_all(back, ep_axes, split_axis=0, concat_axis=0, tiled=True)
        got = got.reshape(e, cap, d)
        combined = _combine_local(got, gates, (idx_flat, slot, keep, tok), t_loc)
        return jax.lax.psum(combined, tp) if tp else combined

    t = b * s
    xt = x.reshape(t, d)
    bspec = pspec("data")
    ep_spec = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    tp_spec = pspec("model")[0]
    out = shard_map(
        f,
        mesh=mesh,
        in_specs=(
            P(bspec[0]), P(), P(ep_spec, None, tp_spec),
            P(ep_spec, None, tp_spec), P(ep_spec, tp_spec, None),
        ),
        out_specs=P(bspec[0]),
        check_rep=False,
    )(xt, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return out.reshape(b, s, d)


# -- PULL: all_gather expert weights over the EP axis --------------------------

def _moe_pull(params, x, experts_per_token, capacity_factor, mesh, replicated_tokens=False):
    b, s, d = x.shape
    e = params["router"].shape[1]
    ep_axes, ep = _ep_axes(e, mesh)
    tp = "model" if "model" in mesh.axis_names else None

    def f(xt, router, wg, wu, wd):
        t_loc = xt.shape[0]
        # Fetch stage (paper Alg. 4): pull the operand data once per batch —
        # bounded by the weight size (k·|E_G| of Remark 3.1), independent of
        # how many tokens are in flight.
        if ep_axes:
            wg = jax.lax.all_gather(wg, ep_axes, axis=0, tiled=True)
            wu = jax.lax.all_gather(wu, ep_axes, axis=0, tiled=True)
            wd = jax.lax.all_gather(wd, ep_axes, axis=0, tiled=True)
        gates, idx = _route(xt, router, experts_per_token)
        n = t_loc * experts_per_token
        cap = _capacity(n, e, capacity_factor)
        buckets, book = _dispatch_local(xt, gates, idx, cap, e)
        # psum deferred past the linear combine (see _moe_push).
        out = _expert_ffn(buckets, wg, wu, wd, None)
        combined = _combine_local(out, gates, book, t_loc)
        return jax.lax.psum(combined, tp) if tp else combined

    t = b * s
    xt = x.reshape(t, d)
    bspec = None if replicated_tokens else pspec("data")[0]
    ep_spec = (ep_axes if len(ep_axes) > 1 else ep_axes[0]) if ep_axes else None
    tp_spec = pspec("model")[0]
    out = shard_map(
        f,
        mesh=mesh,
        in_specs=(
            P(bspec), P(), P(ep_spec, None, tp_spec),
            P(ep_spec, None, tp_spec), P(ep_spec, tp_spec, None),
        ),
        out_specs=P(bspec),
        check_rep=False,
    )(xt, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return out.reshape(b, s, d)


def router_aux_loss(params: Dict, x: jax.Array, experts_per_token: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    e = probs.shape[-1]
    _, idx = jax.lax.top_k(probs, experts_per_token)
    frac_tokens = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return e * jnp.sum(frac_tokens * frac_probs)
