"""Activation/weight sharding helpers, mesh-agnostic.

Model code calls ``shard(x, "data", None, "model")``; the constraint is applied
only for axis names present in the *active* mesh (set by the launcher /
dry-run), so the same model runs unsharded on the 1-device CI box and fully
sharded on the production mesh. The batch axis name ``"data"`` expands to
``("pod", "data")`` automatically when a pod axis exists (multi-pod DP).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, PartitionSpec as P

_state = threading.local()


def active_axes() -> tuple:
    return getattr(_state, "axes", ())


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def axis_size(name: str) -> int:
    mesh = active_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def batch_axes() -> tuple:
    """Mesh axes that carry data parallelism (pod × data)."""
    axes = active_axes()
    return tuple(a for a in ("pod", "data") if a in axes)


@contextlib.contextmanager
def activate(mesh: Optional[Mesh]):
    prev_axes = getattr(_state, "axes", ())
    prev_mesh = getattr(_state, "mesh", None)
    _state.axes = tuple(mesh.axis_names) if mesh is not None else ()
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.axes = prev_axes
        _state.mesh = prev_mesh


def _resolve(axis):
    """Map a logical axis to mesh axes; None/absent axes drop out."""
    axes = active_axes()
    if axis is None:
        return None
    if isinstance(axis, (tuple, list)):
        got = tuple(a for a in axis if a in axes)
        return got if got else None
    if axis == "data" and "pod" in axes:
        return ("pod", "data") if "data" in axes else ("pod",)
    return axis if axis in axes else None


def pspec(*axes) -> P:
    return P(*[_resolve(a) for a in axes])


def shard(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op if unsharded)."""
    if not active_axes():
        return x
    return jax.lax.with_sharding_constraint(x, pspec(*axes))
