"""Core model layers: norms, RoPE, GQA attention (train / prefill / decode),
gated MLPs. Pure-JAX (params are nested dicts), dtype-explicit throughout.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.sharding import shard


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (nrm * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard, partial-dim for chatglm's 2d variant)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jax.Array, positions: jax.Array, theta: float, fraction: float = 1.0):
    """x: [..., S, H, Dh]; positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    inv, rot = rope_freqs(dh, theta, fraction)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, rot/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    rotated = jnp.stack([o1, o2], axis=-1).reshape(*x.shape[:-1], rot)
    out = jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)
    return out


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 1e4
    rope_fraction: float = 1.0
    window: Optional[int] = None        # local (sliding-window) attention
    attn_softcap: Optional[float] = None
    bias: bool = False
    causal: bool = True


def attn_init(key, d_model: int, spec: AttnSpec, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    h, kv, dh = spec.num_heads, spec.num_kv_heads, spec.head_dim
    p = {
        "wq": dense_init(ks[0], (d_model, h * dh), dtype),
        "wk": dense_init(ks[1], (d_model, kv * dh), dtype),
        "wv": dense_init(ks[2], (d_model, kv * dh), dtype),
        "wo": dense_init(ks[3], (h * dh, d_model), dtype),
    }
    if spec.bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kv * dh,), dtype)
        p["bv"] = jnp.zeros((kv * dh,), dtype)
    return p


def _attn_parallel_mode(n_heads: int, seq_len: int) -> str:
    """How attention compute is split over the TP axis.

    "heads": TP on the head axis (the classic megatron split) — only when the
    head count divides the axis; forcing 56 heads onto 16 ways makes GSPMD
    all-gather the f32 score tensors every KV chunk (measured 4.2 TB/device
    on arctic train_4k — §Perf iteration log).
    "seq":   sequence-parallel scores — q and the whole online-softmax state
    shard over the query-sequence dim; K/V are replicated per layer (tiny:
    2·S·kv·dh vs the S²-scaled score gathers they replace).
    """
    from repro.models.sharding import axis_size

    tp = axis_size("model")
    if tp <= 1 or n_heads % tp == 0:
        return "heads"
    return "seq" if seq_len % tp == 0 else "hd"


def _qkv(params, x, spec: AttnSpec):
    b, s, _ = x.shape
    h, kv, dh = spec.num_heads, spec.num_kv_heads, spec.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if spec.bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    mode = _attn_parallel_mode(h, s)
    kv_mode = _attn_parallel_mode(kv, s)
    q_spec = {
        "heads": ("data", None, "model", None),
        "seq": ("data", "model", None, None),
        "hd": ("data", None, None, "model"),
    }[mode]
    kv_spec = {
        "heads": ("data", None, "model", None),
        "hd": ("data", None, None, "model"),
    }[kv_mode if kv_mode != "seq" else "hd"]
    q = shard(q.reshape(b, s, h, dh), *q_spec)
    k = shard(k.reshape(b, s, kv, dh), *kv_spec)
    v = shard(v.reshape(b, s, kv, dh), *kv_spec)
    if mode == "seq":
        # K/V *values* are pulled to every shard (2·S·kv·dh — tiny), but only
        # after the projection ran TP-sharded: computing them replicated made
        # the wk/wv gradients replicate too, costing a 0.43 TB/step all-reduce
        # (§Perf arctic iteration 3).
        k = shard(k, "data", None, None, None)
        v = shard(v, "data", None, None, None)
    return q, k, v


def _sdpa(q, k, v, spec: AttnSpec, q_positions, kv_len_valid=None, chunk=512):
    """Grouped-query online-softmax attention.

    q: [B, Sq, H, Dh]; k,v: [B, Sk, KV, Dh]. Positions give causality; for
    decode, Sq=1 with a cache of Sk entries (kv_len_valid masks the unfilled
    tail). Window masking implements gemma2-style local attention.
    """
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    groups = h // kvh
    qf = q.astype(jnp.float32) * (dh ** -0.5)
    qf = qf.reshape(b, sq, kvh, groups, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = kf.shape[1] // chunk
    kc = kf.reshape(b, n_chunks, chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
    vc = vf.reshape(b, n_chunks, chunk, kvh, dh).transpose(1, 0, 2, 3, 4)

    def step(carry, xs):
        acc, m, l = carry
        ci, kb, vb = xs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qf, kb)
        if spec.attn_softcap is not None:
            s = spec.attn_softcap * jnp.tanh(s / spec.attn_softcap)
        k_pos = ci * chunk + jnp.arange(chunk)
        valid = k_pos[None, :] < (sk if kv_len_valid is None else kv_len_valid[:, None])
        mask = valid[:, None, :]  # [B, 1, C]
        if spec.causal:
            mask = mask & (q_positions[:, :, None] >= k_pos[None, None, :])
        if spec.window is not None:
            mask = mask & (q_positions[:, :, None] - k_pos[None, None, :] < spec.window)
        mask5 = mask[:, :, None, None, :]
        s = jnp.where(mask5, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        # Explicit zeroing: a *fully-masked* chunk (sliding window, cache tail)
        # would otherwise contribute exp(-1e30 − (−1e30)) = 1.
        p = jnp.where(mask5, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha[..., 0][..., None] + jnp.einsum("bqkgc,bckd->bqkgd", p, vb)
        return (acc, m_new, l), None

    init = (
        jnp.zeros((b, sq, kvh, groups, dh), jnp.float32),
        jnp.full((b, sq, kvh, groups, 1), -1e30, jnp.float32),
        jnp.zeros((b, sq, kvh, groups, 1), jnp.float32),
    )
    (acc, m, l), _ = jax.lax.scan(step, init, (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l[..., 0][..., None], 1e-30)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def attention_block(params, x, spec: AttnSpec, positions, cache=None, chunk=512):
    """Returns (out, new_cache). cache = dict(k, v [B, Smax, KV, Dh], len [B])."""
    b, s, d = x.shape
    q, k, v = _qkv(params, x, spec)
    q = apply_rope(q, positions, spec.rope_theta, spec.rope_fraction)
    k = apply_rope(k, positions, spec.rope_theta, spec.rope_fraction)
    new_cache = None
    kv_valid = None
    if cache is not None:
        # dynamic insert at position `len` (uniform across batch for serving)
        insert = cache["len"]
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, insert, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, insert, 0, 0))
        new_cache = {"k": kc, "v": vc, "len": insert + s}
        k, v = kc, vc
        kv_valid = jnp.full((b,), insert + s, jnp.int32)
    out = _sdpa(q, k, v, spec, positions, kv_valid, chunk=chunk)
    out = out.reshape(b, s, spec.num_heads * spec.head_dim)
    wo = params["wo"]
    if _attn_parallel_mode(spec.num_heads, s) == "seq":
        # Sequence-parallel output projection: *pull* the wo weight (one
        # ~100 MB gather per layer, Remark 3.1's k·|E_G| bound) instead of
        # *pushing* the s-sharded activations through a resharding + TP psum
        # (measured 0.66 TB/device/step on arctic — §Perf iteration log).
        wo = shard(wo, None, None)
    out = out @ wo
    return shard(out, "data", None, None), new_cache


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def mlp_block(params, x):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = shard(h, "data", None, "model")
    return shard(h @ params["w_down"], "data", None, None)
