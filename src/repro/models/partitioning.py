"""Parameter → PartitionSpec rules (megatron TP over "model", optional FSDP
over "data", EP for experts over the (pod, data) product).

Rules are path-based over the params pytree; stacked scan dims (leading
``num_groups`` axis on block params) are never sharded... except under FSDP,
where the stacked-layer axis is the ZeRO shard axis (scan gathers one layer
slice at a time, which is exactly per-layer FSDP all-gather, overlappable by
XLA's latency-hiding scheduler).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import ModelConfig

# name → spec template for the *last ndim* axes (no stacked dim).
_COL = ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "dt_proj", "wr", "wg", "w_b")
_ROW = ("wo", "w_down", "out_proj", "x_proj")
_VEC_MODEL = ("conv_b", "dt_bias", "d_skip", "w0", "ln_out")


def _leaf_spec(path: str, ndim: int, cfg: ModelConfig, ep_axis) -> P:
    name = path.split("/")[-1]
    moe = "/moe/" in path or path.endswith("router")
    if moe and ndim >= 3:
        # expert tensors [E, d, ff] / [E, ff, d]
        if name in ("w_gate", "w_up"):
            return P(ep_axis, None, "model")
        if name == "w_down":
            return P(ep_axis, "model", None)
    if name == "router":
        return P(None, None)
    if name == "embed":
        return P("model", None)
    if name == "lm_head":
        return P(None, "model")
    if name in _COL and ndim >= 2:
        return P(*([None] * (ndim - 1)), "model")
    if name in _ROW and ndim >= 2:
        return P("model", *([None] * (ndim - 1)))
    if name == "conv_w":
        return P(None, "model")
    if name == "a_log":
        return P("model", None)
    if name == "u":
        return P("model", None)
    if name in _VEC_MODEL:
        return P("model")
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(cfg: ModelConfig, params_tree, *, fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching ``params_tree`` (arrays or ShapeDtypeStructs)."""
    ep_axis = ("pod", "data") if cfg.num_experts else None

    def one(path, leaf):
        pstr = _path_str(path)
        ndim = len(leaf.shape)
        stacked = pstr.startswith("blocks/") or pstr.startswith("encoder/") or pstr.startswith("cross/")
        base_ndim = ndim - 1 if stacked else ndim
        spec = _leaf_spec(pstr, base_ndim, cfg, ep_axis)
        used = {a for ax in spec for a in (ax if isinstance(ax, tuple) else (ax,))}
        if fsdp and base_ndim >= 2 and "data" not in used:
            # ZeRO-3: add "data" on the first unsharded *feature* axis whose
            # size divides (never the stacked scan axis — group counts like
            # 35/40 don't divide the data axis; d_model/d_ff always do).
            axes = list(spec)
            shape_tail = leaf.shape[1:] if stacked else leaf.shape
            for i, ax in enumerate(axes):
                if ax is None and shape_tail[i] % 16 == 0 and shape_tail[i] >= 256:
                    axes[i] = "data"
                    break
            spec = P(*axes)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(one, params_tree)


def param_shardings(cfg: ModelConfig, params_tree, mesh: Mesh, *, fsdp: bool = False):
    specs = param_pspecs(cfg, params_tree, fsdp=fsdp)

    def fix(spec, leaf):
        # Drop axes not present in this mesh (single-pod vs multi-pod). For
        # *tuple* axes (expert EP over pod×data) additionally enforce exact
        # divisibility by dropping leading axes — the MoE shard_map requires
        # it; single named axes may stay uneven (GSPMD pads, e.g. 56 heads/16).
        cleaned = []
        for i, ax in enumerate(spec):
            dim = leaf.shape[i] if i < len(leaf.shape) else 1
            if ax is None:
                cleaned.append(None)
            elif isinstance(ax, tuple):
                got = tuple(a for a in ax if a in mesh.axis_names)
                while got:
                    size = 1
                    for a in got:
                        size *= mesh.shape[a]
                    if dim % size == 0:
                        break
                    got = got[1:]
                cleaned.append(got if got else None)
            else:
                cleaned.append(ax if ax in mesh.axis_names else None)
        return NamedSharding(mesh, P(*cleaned))

    return jax.tree.map(fix, specs, params_tree)


def count_params(params_tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_tree))
