"""Unified model zoo: decoder-only / MoE / hybrid-SSM / enc-dec architectures.

One config dataclass describes every assigned architecture; layers are grouped
by the repeating pattern period and scanned (``lax.scan`` over stacked params)
so the compiled HLO stays small and compile times are flat in depth. Mixers:
global/local GQA attention, Mamba, RWKV6. MLPs: dense SwiGLU, MoE (with HUGE
push/pull-hybrid dispatch), arctic-style MoE+dense-residual.

API:
  init_params(cfg, key)                        → params pytree (smoke scale)
  param_shapes(cfg)                            → ShapeDtypeStruct pytree (dry-run)
  forward(cfg, params, batch)                  → logits
  loss_fn(cfg, params, batch)                  → scalar loss
  init_cache(cfg, batch, max_len)              → decode cache (shapes or arrays)
  prefill(cfg, params, batch, max_len)         → (cache, last_logits)
  decode_step(cfg, params, cache, tokens, pos) → (logits, cache)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hybrid_comm import moe_dispatch_mode
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    AttnSpec,
    attention_block,
    attn_init,
    dense_init,
    dtype_of,
    mlp_block,
    mlp_init,
    rmsnorm,
)
from repro.models.sharding import active_mesh, axis_size, shard


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads
    # mixer / mlp patterns, cycled over layers
    layer_pattern: Tuple[str, ...] = ("attn",)          # attn | attn_local | mamba | rwkv
    mlp_pattern: Tuple[str, ...] = ("dense",)           # dense | moe | moe_dense
    # attention
    rope_theta: float = 1e4
    rope_fraction: float = 1.0
    local_window: int = 4096
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    qkv_bias: bool = False
    tie_embeddings: bool = False
    attn_chunk: int = 512
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_comm: str = "auto"           # auto | push | pull | local
    # ssm
    ssm_state: int = 16
    ssm_conv: int = 4
    mamba_expand: int = 2
    # enc-dec
    encoder_layers: int = 0
    # modality frontend stub: inputs arrive as precomputed embeddings
    frontend: Optional[str] = None   # "audio" | "vision"
    frontend_len: int = 0
    # misc
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    sub_quadratic: bool = False      # eligible for long_500k decode

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to a multiple of 64 so the vocab axis shards
        evenly over model=16 (padded logits are masked to -inf)."""
        return ((self.vocab_size + 63) // 64) * 64

    @property
    def period(self) -> int:
        return int(math.lcm(len(self.layer_pattern), len(self.mlp_pattern)))

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.period == 0, (self.num_layers, self.period)
        return self.num_layers // self.period

    def mixer_at(self, pos: int) -> str:
        return self.layer_pattern[pos % len(self.layer_pattern)]

    def mlp_at(self, pos: int) -> str:
        return self.mlp_pattern[pos % len(self.mlp_pattern)]

    def attn_spec(self, local: bool) -> AttnSpec:
        return AttnSpec(
            num_heads=self.num_heads,
            num_kv_heads=self.num_kv_heads,
            head_dim=self.hd,
            rope_theta=self.rope_theta,
            rope_fraction=self.rope_fraction,
            window=self.local_window if local else None,
            attn_softcap=self.attn_softcap,
            bias=self.qkv_bias,
            causal=True,
        )

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    def param_count(self) -> int:
        """Analytic total parameter count (for roofline MODEL_FLOPS)."""
        d, ff, v, hd = self.d_model, self.d_ff, self.vocab_size, self.hd
        total = v * d + (0 if self.tie_embeddings else v * d)
        for l in range(self.num_layers):
            mixer = self.mixer_at(l)
            if mixer in ("attn", "attn_local"):
                total += d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d
            elif mixer == "mamba":
                di = self.mamba_expand * d
                total += d * 2 * di + di * d + di * (max(1, d // 16) + 2 * self.ssm_state) + di * self.ssm_conv
            elif mixer == "rwkv":
                total += 5 * d * d + 2 * d * 64
            mlp = self.mlp_at(l)
            if mlp in ("dense",):
                total += 3 * d * ff
            if mlp in ("moe", "moe_dense"):
                total += 3 * d * self.moe_d_ff * self.num_experts + d * self.num_experts
            if mlp == "moe_dense":
                total += 3 * d * ff
        if self.encoder_layers:
            # encoder self-attn + mlp + decoder cross-attn
            total += self.encoder_layers * (
                d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d + 3 * d * ff
            )
            total += self.num_layers * (d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.num_layers_moe() * 3 * d * self.moe_d_ff * self.num_experts
        return dense + self.num_layers_moe() * 3 * d * self.moe_d_ff * self.experts_per_token

    def num_layers_moe(self) -> int:
        return sum(1 for l in range(self.num_layers) if self.mlp_at(l) in ("moe", "moe_dense"))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _position_init(cfg: ModelConfig, pos: int, key) -> Dict:
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                         "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    mixer = cfg.mixer_at(pos)
    if mixer in ("attn", "attn_local"):
        p["attn"] = attn_init(ks[0], cfg.d_model, cfg.attn_spec(mixer == "attn_local"), dt)
    elif mixer == "mamba":
        p["mamba"] = ssm_mod.mamba_init(
            ks[0], cfg.d_model, expand=cfg.mamba_expand, state=cfg.ssm_state,
            conv_dim=cfg.ssm_conv, dtype=dt,
        )
    elif mixer == "rwkv":
        p["rwkv"] = ssm_mod.rwkv6_init(ks[0], cfg.d_model, cfg.num_heads, dtype=dt)
    mlp = cfg.mlp_at(pos)
    if mlp in ("dense", "moe_dense"):
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt)
    if mlp in ("moe", "moe_dense"):
        p["moe"] = moe_mod.moe_init(ks[2], cfg.d_model, cfg.moe_d_ff, cfg.num_experts, dt)
    return p


def init_params(cfg: ModelConfig, key) -> Dict:
    dt = dtype_of(cfg.dtype)
    keys = jax.random.split(key, cfg.num_groups * cfg.period + 8)
    blocks = []
    for pos in range(cfg.period):
        per_group = [
            _position_init(cfg, pos, keys[g * cfg.period + pos]) for g in range(cfg.num_groups)
        ]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group))
    params: Dict[str, Any] = {
        "embed": dense_init(keys[-1], (cfg.vocab_padded, cfg.d_model), dt, scale=0.02),
        "blocks": tuple(blocks),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-2], (cfg.d_model, cfg.vocab_padded), dt)
    if cfg.encoder_layers:
        enc = [
            {
                "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                "attn": attn_init(keys[i], cfg.d_model, cfg.attn_spec(False), dt),
                "mlp": mlp_init(keys[i + 1], cfg.d_model, cfg.d_ff, dt),
            }
            for i in range(cfg.encoder_layers)
        ]
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        xa = [
            {
                "ln": jnp.zeros((cfg.d_model,), jnp.float32),
                "attn": attn_init(keys[-3 - i], cfg.d_model, cfg.attn_spec(False), dt),
            }
            for i in range(cfg.num_layers)
        ]
        params["cross"] = jax.tree.map(lambda *xs: jnp.stack(xs), *xa)
    return params


def param_shapes(cfg: ModelConfig):
    """Abstract params for the dry-run — no allocation."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _moe_comm_mode(cfg: ModelConfig, tokens_per_step: int) -> str:
    if cfg.moe_comm != "auto":
        return cfg.moe_comm
    dp = axis_size("data") * axis_size("pod")
    if dp <= 1:
        return "local"
    dec = moe_dispatch_mode(
        tokens_per_step=tokens_per_step, d_model=cfg.d_model, d_ff=cfg.moe_d_ff,
        num_experts=cfg.num_experts, experts_per_token=cfg.experts_per_token,
        dp_degree=dp,
    )
    return dec.mode


def _apply_position(cfg: ModelConfig, pos: int, p: Dict, x, positions, cache,
                    comm_mode: str, memory=None):
    mixer = cfg.mixer_at(pos)
    new_cache = {}
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if mixer in ("attn", "attn_local"):
        spec = cfg.attn_spec(mixer == "attn_local")
        att, kv = attention_block(
            p["attn"], h, spec, positions,
            cache.get("attn") if cache else None, chunk=cfg.attn_chunk,
        )
        x = x + att
        if kv is not None:
            new_cache["attn"] = kv
    elif mixer == "mamba":
        y, st = ssm_mod.mamba_block(p["mamba"], h, None if cache is None else cache.get("mamba"))
        x = x + y
        if cache is not None:
            new_cache["mamba"] = st
    elif mixer == "rwkv":
        y, st = ssm_mod.rwkv6_block(
            p["rwkv"], h, cfg.num_heads, None if cache is None else cache.get("rwkv")
        )
        x = x + y
        if cache is not None:
            new_cache["rwkv"] = st
    # cross-attention (enc-dec decoders): memory is the encoder output
    if memory is not None and "cross" in p:
        hc = rmsnorm(x, p["cross"]["ln"], cfg.norm_eps)
        spec = dataclasses.replace(cfg.attn_spec(False), causal=False)
        mem_k, mem_v = memory
        xa = _cross_attention(p["cross"]["attn"], hc, mem_k, mem_v, spec)
        x = x + xa
    mlp = cfg.mlp_at(pos)
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    delta = 0.0
    if mlp in ("dense", "moe_dense"):
        delta = mlp_block(p["mlp"], h2)
    if mlp in ("moe", "moe_dense"):
        delta = delta + moe_mod.moe_block(
            p["moe"], h2, experts_per_token=cfg.experts_per_token, comm_mode=comm_mode
        )
    x = x + delta
    return x, new_cache


def _cross_attention(p, h, mem_k, mem_v, spec):
    """Decoder→encoder attention with precomputed K/V memory."""
    b, s, d = h.shape
    hq, kvh, dh = spec.num_heads, spec.num_kv_heads, spec.head_dim
    q = (h @ p["wq"]).reshape(b, s, hq, dh)
    from repro.models.layers import _sdpa  # shared inner attention
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = _sdpa(q, mem_k, mem_v, dataclasses.replace(spec, causal=False), pos)
    return out.reshape(b, s, hq * dh) @ p["wo"]


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params, tokens, frontend_emb):
    dt = dtype_of(cfg.dtype)
    emb = shard(params["embed"], "model", None)
    x = jnp.take(emb, jnp.clip(tokens, 0, cfg.vocab_size - 1), axis=0) * (cfg.d_model ** 0.5)
    x = x.astype(dt)
    if frontend_emb is not None and cfg.family != "audio":
        x = jnp.concatenate([frontend_emb.astype(dt), x], axis=1)
    return shard(x, "data", None, None)


def _logits(cfg: ModelConfig, params, x):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    head = shard(head, None, "model")
    logits = x @ head
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.vocab_padded != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    return shard(logits, "data", None, "model")


def _encode(cfg: ModelConfig, params, enc_emb):
    """Run the encoder stack over frontend embeddings (seamless)."""
    x = shard(enc_emb.astype(dtype_of(cfg.dtype)), "data", None, None)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

    def body(x, p):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        spec = dataclasses.replace(cfg.attn_spec(False), causal=False)
        att, _ = attention_block(p["attn"], h, spec, pos, None, chunk=cfg.attn_chunk)
        x = x + att
        x = x + mlp_block(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _memory_kv(cfg, cross_p, enc_out):
    """Precompute cross-attention K/V from encoder output (per layer)."""
    b, s, d = enc_out.shape
    kvh, dh = cfg.num_kv_heads, cfg.hd

    def one(p):
        k = (enc_out @ p["attn"]["wk"]).reshape(b, s, kvh, dh)
        v = (enc_out @ p["attn"]["wv"]).reshape(b, s, kvh, dh)
        return k, v

    return jax.vmap(one)(cross_p)  # stacked [L, ...]


def forward(cfg: ModelConfig, params, batch: Dict) -> jax.Array:
    tokens = batch["tokens"]
    frontend_emb = batch.get("frontend")
    x = _embed(cfg, params, tokens, frontend_emb)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    tokens_per_step = b * s
    comm_mode = _moe_comm_mode(cfg, tokens_per_step)

    memory = None
    cross_kv = None
    if cfg.encoder_layers:
        assert frontend_emb is not None, "enc-dec needs encoder (frontend) inputs"
        enc_out = _encode(cfg, params, frontend_emb)
        cross_kv = _memory_kv(cfg, params["cross"], enc_out)

    def group(x, xs):
        if cfg.encoder_layers:
            gp, cross_g = xs
        else:
            gp, cross_g = xs, None
        for pos in range(cfg.period):
            p = dict(gp[pos])
            mem = None
            if cross_g is not None:
                p["cross"] = {
                    "ln": cross_g["ln"][pos],
                    "attn": jax.tree.map(lambda t: t[pos], cross_g["attn"]),
                }
                mem = (cross_g["k"][pos], cross_g["v"][pos])
            x, _ = _apply_position(cfg, pos, p, x, positions, None, comm_mode, memory=mem)
        return x, None

    stacked = _group_stack(cfg, params)
    if cfg.encoder_layers:
        cross_stack = _cross_group_stack(cfg, params, cross_kv)
        x, _ = jax.lax.scan(jax.checkpoint(group), x, (stacked, cross_stack))
    else:
        x, _ = jax.lax.scan(jax.checkpoint(group), x, stacked)
    return _logits(cfg, params, x)


def _group_stack(cfg: ModelConfig, params):
    """blocks is a tuple of per-position trees stacked [n_groups, ...]; scan
    needs xs indexed by group → re-expose as {pos: tree} dict."""
    return {pos: params["blocks"][pos] for pos in range(cfg.period)}


def _cross_group_stack(cfg: ModelConfig, params, cross_kv):
    k, v = cross_kv
    lp = cfg.period
    ng = cfg.num_groups

    def regroup(t):
        return t.reshape(ng, lp, *t.shape[1:])

    return {
        "ln": regroup(params["cross"]["ln"]),
        "attn": jax.tree.map(regroup, params["cross"]["attn"]),
        "k": regroup(k),
        "v": regroup(v),
    }


def loss_fn(cfg: ModelConfig, params, batch: Dict) -> jax.Array:
    logits = forward(cfg, params, batch)
    tokens = batch["tokens"]
    front = 0
    if batch.get("frontend") is not None and cfg.family != "audio" and not cfg.encoder_layers:
        front = batch["frontend"].shape[1]
    logits_txt = logits[:, front:, :]
    targets = tokens[:, 1:]
    preds = logits_txt[:, :-1, :].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(preds, axis=-1)
    gold = jnp.take_along_axis(preds, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(nll) if mask is None else mask[:, 1:].astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def _position_cache(cfg: ModelConfig, pos: int, batch: int, max_len: int):
    dt = dtype_of(cfg.dtype)
    mixer = cfg.mixer_at(pos)
    ng = cfg.num_groups
    if mixer in ("attn", "attn_local"):
        kv = cfg.num_kv_heads
        # local layers never need more than the window
        length = min(max_len, cfg.local_window) if mixer == "attn_local" else max_len
        return {
            "attn": {
                "k": jnp.zeros((ng, batch, max_len, kv, cfg.hd), dt),
                "v": jnp.zeros((ng, batch, max_len, kv, cfg.hd), dt),
                "len": jnp.zeros((ng,), jnp.int32),
            }
        }
    if mixer == "mamba":
        di = cfg.mamba_expand * cfg.d_model
        return {
            "mamba": (
                jnp.zeros((ng, batch, cfg.ssm_conv - 1, di), dt),
                jnp.zeros((ng, batch, di, cfg.ssm_state), jnp.float32),
            )
        }
    if mixer == "rwkv":
        hd = cfg.d_model // cfg.num_heads
        return {
            "rwkv": (
                jnp.zeros((ng, batch, cfg.d_model), dt),
                jnp.zeros((ng, batch, cfg.num_heads, hd, hd), jnp.float32),
            )
        }
    return {}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    cache = {f"pos{pos}": _position_cache(cfg, pos, batch, max_len) for pos in range(cfg.period)}
    if cfg.encoder_layers:
        enc_len = cfg.frontend_len or max_len
        kv, dh = cfg.num_kv_heads, cfg.hd
        cache["memory"] = {
            "k": jnp.zeros((cfg.num_layers, batch, enc_len, kv, dh), dtype_of(cfg.dtype)),
            "v": jnp.zeros((cfg.num_layers, batch, enc_len, kv, dh), dtype_of(cfg.dtype)),
        }
    return cache


def _run_with_cache(cfg: ModelConfig, params, x, positions, cache, comm_mode):
    stacked = _group_stack(cfg, params)
    mem = cache.get("memory") if cfg.encoder_layers else None
    cross_stack = None
    if cfg.encoder_layers:
        cross_stack = _cross_group_stack(cfg, params, (mem["k"], mem["v"]))

    def group(x, xs):
        if cross_stack is not None:
            gp, gc, cross_g = xs
        else:
            (gp, gc), cross_g = xs, None
        new_gc = {}
        for pos in range(cfg.period):
            p = dict(gp[pos])
            memkv = None
            if cross_g is not None:
                p["cross"] = {
                    "ln": cross_g["ln"][pos],
                    "attn": jax.tree.map(lambda t: t[pos], cross_g["attn"]),
                }
                memkv = (cross_g["k"][pos], cross_g["v"][pos])
            layer_cache = gc[f"pos{pos}"]
            x, nc = _apply_position(cfg, pos, p, x, positions, layer_cache, comm_mode, memory=memkv)
            new_gc[f"pos{pos}"] = nc
        return x, new_gc

    layer_cache = {f"pos{pos}": cache[f"pos{pos}"] for pos in range(cfg.period)}
    if cross_stack is not None:
        x, new_cache = jax.lax.scan(group, x, (stacked, layer_cache, cross_stack))
    else:
        x, new_cache = jax.lax.scan(group, x, (stacked, layer_cache))
    out_cache = dict(new_cache)
    if cfg.encoder_layers:
        out_cache["memory"] = cache["memory"]
    return x, out_cache


def prefill(cfg: ModelConfig, params, batch: Dict, max_len: int):
    tokens = batch["tokens"]
    b = tokens.shape[0]
    cache = init_cache(cfg, b, max_len)
    if cfg.encoder_layers:
        enc_out = _encode(cfg, params, batch["frontend"])
        k, v = _memory_kv(cfg, params["cross"], enc_out)
        cache["memory"] = {"k": k, "v": v}
    x = _embed(cfg, params, tokens, batch.get("frontend"))
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    comm = _moe_comm_mode(cfg, b * s)
    x, cache = _run_with_cache(cfg, params, x, positions, cache, comm)
    return cache, _logits(cfg, params, x[:, -1:, :])


def decode_step(cfg: ModelConfig, params, cache, tokens, pos_scalar):
    """tokens [B, 1]; pos_scalar int32[] current position."""
    b = tokens.shape[0]
    x = _embed(cfg, params, tokens, None)
    positions = jnp.broadcast_to(pos_scalar[None, None], (b, 1))
    comm = _moe_comm_mode(cfg, b)
    x, cache = _run_with_cache(cfg, params, x, positions, cache, comm)
    return _logits(cfg, params, x), cache
