"""Attention-free token mixers: Mamba (jamba's 7/8 layers) and RWKV6.

Both are linear recurrences; training/prefill uses chunked scans (bounded
memory, remat-friendly), decode carries O(1) state per layer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6 import ops as rwkv_ops
from repro.models.layers import dense_init, rmsnorm
from repro.models.sharding import shard


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------

def mamba_init(key, d_model: int, *, expand: int = 2, state: int = 16,
               conv_dim: int = 4, dt_rank: int | None = None, dtype=jnp.bfloat16) -> Dict:
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_inner), dtype),
        "conv_w": dense_init(ks[1], (conv_dim, d_inner), dtype, scale=0.5),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], (d_inner, dt_rank + 2 * state), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_inner), dtype),
        "dt_bias": jnp.zeros((d_inner,), jnp.float32),
        "a_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, state + 1, dtype=jnp.float32), (d_inner, state))
        ),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_inner, d_model), dtype),
    }


def _causal_conv(x, w, b, tail=None):
    """x [B,S,Di], w [K,Di] depthwise causal conv. tail [B,K-1,Di] carries
    decode state; returns (y, new_tail)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    new_tail = xp[:, -(k - 1):] if k > 1 else tail
    return y + b, new_tail


def _ssm_scan_chunked(a, bx, c, h0, chunk: int):
    """y_t = Σ_s h_t[·,s]·c_t[s],  h_t = a_t ⊙ h_{t-1} + bx_t.

    a, bx: [B, T, Di, S]; c: [B, T, S]; h0: [B, Di, S].
    Chunked lax.scan: O(T/chunk) checkpoints, chunk recomputed in backward.
    """
    b, t, di, s = a.shape
    chunk = min(chunk, t)
    assert t % chunk == 0
    n = t // chunk

    def run_chunk(h, xs):
        ac, bxc, cc = xs  # [B, C, Di, S], [B, C, S]

        def step(h, inner):
            at, bt, ct = inner
            h = at * h + bt
            y = jnp.einsum("bds,bs->bd", h, ct)
            return h, y

        h, ys = jax.lax.scan(
            step, h,
            (ac.transpose(1, 0, 2, 3), bxc.transpose(1, 0, 2, 3), cc.transpose(1, 0, 2)),
        )
        return h, ys.transpose(1, 0, 2)  # [B, C, Di]

    ar = a.reshape(b, n, chunk, di, s).transpose(1, 0, 2, 3, 4)
    bxr = bx.reshape(b, n, chunk, di, s).transpose(1, 0, 2, 3, 4)
    cr = c.reshape(b, n, chunk, s).transpose(1, 0, 2, 3)
    h, ys = jax.lax.scan(jax.checkpoint(run_chunk), h0, (ar, bxr, cr))
    return ys.transpose(1, 0, 2, 3).reshape(b, t, di), h


def mamba_block(params: Dict, x: jax.Array, state=None, chunk: int = 128):
    """x [B,S,d] → (y [B,S,d], new_state). state = (conv_tail, h)."""
    b, s, d = x.shape
    d_inner = params["in_proj"].shape[1] // 2
    nstate = params["a_log"].shape[1]
    xz = x @ params["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = shard(x1, "data", None, "model")
    conv_tail = None if state is None else state[0]
    x1, new_tail = _causal_conv(x1, params["conv_w"], params["conv_b"], conv_tail)
    x1 = jax.nn.silu(x1)

    proj = x1 @ params["x_proj"]
    dt_rank = params["dt_proj"].shape[0]
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + nstate], axis=-1)
    dt = jax.nn.softplus(
        (dt @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"]
    )  # [B,S,Di]
    a = -jnp.exp(params["a_log"])                       # [Di, S]
    decay = jnp.exp(dt[..., None] * a)                  # [B,S,Di,S]
    bx = (dt * x1.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[:, :, None, :]

    h0 = (
        jnp.zeros((b, d_inner, nstate), jnp.float32) if state is None else state[1]
    )
    if s == 1:  # decode fast path
        h = decay[:, 0] * h0 + bx[:, 0]
        y = jnp.einsum("bds,bs->bd", h, cmat.astype(jnp.float32)[:, 0])[:, None]
        new_h = h
    else:
        y, new_h = _ssm_scan_chunked(decay, bx, cmat.astype(jnp.float32), h0, chunk)
    y = y + params["d_skip"] * x1.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    y = shard(y, "data", None, "model")
    out = y @ params["out_proj"]
    return shard(out, "data", None, None), (new_tail, new_h)


def mamba_state_shape(cfg_d_model: int, batch: int, *, expand=2, state=16, conv_dim=4):
    d_inner = expand * cfg_d_model
    return (
        (batch, conv_dim - 1, d_inner),   # conv tail
        (batch, d_inner, state),          # h
    )


# ---------------------------------------------------------------------------
# RWKV6 block
# ---------------------------------------------------------------------------

def rwkv6_init(key, d_model: int, num_heads: int, dtype=jnp.bfloat16, lora: int = 64) -> Dict:
    hd = d_model // num_heads
    ks = jax.random.split(key, 10)
    return {
        "mix_r": jnp.full((d_model,), 0.5, jnp.float32),
        "mix_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mix_v": jnp.full((d_model,), 0.5, jnp.float32),
        "mix_w": jnp.full((d_model,), 0.5, jnp.float32),
        "mix_g": jnp.full((d_model,), 0.5, jnp.float32),
        "wr": dense_init(ks[0], (d_model, d_model), dtype),
        "wk": dense_init(ks[1], (d_model, d_model), dtype),
        "wv": dense_init(ks[2], (d_model, d_model), dtype),
        "wg": dense_init(ks[3], (d_model, d_model), dtype),
        "w0": jnp.full((d_model,), -2.0, jnp.float32),
        "w_a": dense_init(ks[4], (d_model, lora), dtype, scale=0.01),
        "w_b": dense_init(ks[5], (lora, d_model), dtype, scale=0.01),
        "u": dense_init(ks[6], (num_heads, hd), jnp.float32, scale=0.3),
        "ln_out": jnp.ones((d_model,), jnp.float32),
        "wo": dense_init(ks[7], (d_model, d_model), dtype),
    }


def rwkv6_block(params: Dict, x: jax.Array, num_heads: int, state=None, chunk: int = 64):
    """x [B,S,d] → (y, new_state). state = (x_prev [B,d], S [B,H,hd,hd])."""
    b, s, d = x.shape
    hd = d // num_heads
    x_prev = jnp.zeros((b, d), x.dtype) if state is None else state[0]
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)  # token shift

    def mix(mu):
        return x + mu.astype(x.dtype) * (xs - x)

    r = mix(params["mix_r"]) @ params["wr"]
    k = mix(params["mix_k"]) @ params["wk"]
    v = mix(params["mix_v"]) @ params["wv"]
    g = mix(params["mix_g"]) @ params["wg"]
    xw = mix(params["mix_w"])
    # Data-dependent decay (the Finch contribution): per-channel LoRA.
    # Upper clamp 1.2 bounds |log w| ≤ e^1.2 ≈ 3.3 per step: decays faster
    # than that zero the state within ~5 tokens anyway, and the bound is what
    # lets the chunked path use the stable factored matmul (kernels/rwkv6).
    logdecay = params["w0"] + (jnp.tanh(xw @ params["w_a"]) @ params["w_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(jnp.clip(logdecay, -8.0, 1.2)))        # (0.036, 1)

    def heads(t):  # [B,S,d] -> [B*H, S, hd]
        return t.reshape(b, s, num_heads, hd).transpose(0, 2, 1, 3).reshape(b * num_heads, s, hd)

    u = jnp.broadcast_to(params["u"][None], (b, num_heads, hd)).reshape(b * num_heads, hd)
    if s == 1 and state is not None:
        s_in = state[1].reshape(b * num_heads, hd, hd)
        s_out, o = rwkv_ops.rwkv6_decode_step(
            s_in, heads(r)[:, 0], heads(k)[:, 0], heads(v)[:, 0],
            heads(w.astype(x.dtype))[:, 0], u,
        )
        o = o[:, None]
        new_s = s_out.reshape(b, num_heads, hd, hd)
    elif state is not None:
        # prefill: chunked scan that also returns the carried state.
        ck = chunk if s % chunk == 0 else 1
        o, s_fin = rwkv_ops.rwkv6_chunked(
            heads(r), heads(k), heads(v), heads(w.astype(x.dtype)), u,
            chunk=ck, return_state=True,
        )
        new_s = s_fin.reshape(b, num_heads, hd, hd)
    else:
        o = rwkv_ops.rwkv6(heads(r), heads(k), heads(v), heads(w.astype(x.dtype)), u, chunk=chunk)
        new_s = None  # full-sequence training: state not carried
    o = o.reshape(b, num_heads, s, hd).transpose(0, 2, 1, 3).reshape(b, s, d)
    # per-head group norm
    o = rmsnorm(o.reshape(b, s, num_heads, hd), jnp.zeros((hd,), jnp.float32)).reshape(b, s, d)
    o = (o.astype(x.dtype) * jax.nn.silu(g)) * params["ln_out"].astype(x.dtype)
    out = o @ params["wo"]
    new_xprev = x[:, -1]
    return shard(out, "data", None, None), (new_xprev, new_s)
